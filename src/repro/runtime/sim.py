"""Discrete-event simulator (virtual time) for policy dynamics.

The container has one physical core; the paper's machines have 48/64.  The
simulator executes task graphs against a :class:`MachineModel` in virtual
time, driving the *real* policy / manager / monitor / predictor / broker
code (the same objects used by the threaded executor), so policy behaviour
— idle/resume churn, spin energy, DLB call counts, prediction dynamics —
is reproduced deterministically.

Event model (no poll storms):

* Workers entering ``SPIN`` poll **once**; an empty poll either parks them
  (busy/prediction: they are woken by work arrival or a prediction tick)
  or schedules a single ``SPIN_EXPIRE`` event (hybrid-style budgets are
  collapsed into one event via ``spin_count_override``).
* Work arrival dispatches to spinning workers instantly (the "instant
  reaction" of busy polling), then applies Alg. 2 resumes (with
  ``resume_latency``), then DLB acquisition for sharing policies.
* Prediction ticks fire every ``f`` virtual seconds, re-evaluating
  spinning workers (trim) and idle workers (grow) — §3.1: "the current
  number of CPUs can progressively be trimmed or increased to meet the
  prediction".

Spin time is integrated continuously by the :class:`EnergyMeter` (a parked
spinning worker burns ``P_spin`` for the whole interval), so avoiding poll
events does not distort energy.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, replace
from typing import Any

from ..core.arbiter import AppPlan, ClusterArbiter
from ..core.conditions import (ConditionTimeline, MachineConditions,
                               Perturbation, PerturbationKind)
from ..core.energy import PowerModel
from ..core.events import EventBus, EventKind, RuntimeEvent
from ..core.governor import (DEFAULT_MIN_SAMPLES, GovernorReport,
                             GovernorSpec, ResourceGovernor)
from ..core.manager import WorkerState
from ..core.policies import PollDecision
from ..core.prediction import DEFAULT_PREDICTION_RATE_S, PredictionConfig
from ..core.sharing import ResourceBroker
from ..core.topology import CoreTopology
from ..workloads.arrivals import ArrivalProcess
from .cluster import ClusterModel
from .machine import MachineModel
from .scheduler import Scheduler
from .task import Task, TaskGraph

__all__ = ["SimJobSpec", "SimReport", "SimCluster", "SimExecutor"]

#: kept as an alias so downstream code reads one schema everywhere
SimReport = GovernorReport

# Event kinds (sorted lexically only via seq tiebreak; kind order irrelevant)
# _FAULT/_THROTTLE/_POWER fire machine-condition perturbations (fail /
# recover / straggle, thermal caps, power caps) from a ConditionTimeline.
(_FINISH, _TICK, _RESUME, _SPIN_EXPIRE, _ARRIVE,
 _FAULT, _THROTTLE, _POWER) = range(8)

_heappush = heapq.heappush


@dataclass
class SimJobSpec:
    """Declarative description of one job in the cluster.

    The resource stack is described by ``governor`` (a
    :class:`~repro.core.governor.GovernorSpec`); the flat kwargs below it
    remain as conveniences and are folded into a spec when ``governor``
    is not given.
    """

    name: str
    graph: TaskGraph
    policy: str = "busy"            # any registered policy name
    cpus: list[int] | None = None   # global cpu ids owned by the job
    monitoring: bool | None = None  # default: on iff policy needs it
    prediction_rate_s: float = DEFAULT_PREDICTION_RATE_S
    spin_budget: int = 100
    min_samples: int = DEFAULT_MIN_SAMPLES
    power: PowerModel | None = None
    governor: GovernorSpec | None = None  # overrides the kwargs above
    #: open-workload mode: release tasks over virtual time instead of
    #: submitting the whole graph at t=0.  The process stamps
    #: ``Task.release_time`` in task order; tasks that already carry a
    #: release time (e.g. a replayed trace) are honored when this is None.
    arrivals: ArrivalProcess | None = None
    #: runtime event bus shared with trace recorders; None ⇒ per-job bus
    bus: EventBus | None = None
    #: multi-node clusters: the app's home node (None ⇒ the node of the
    #: first owned cpu, or node 0 with default cpus).  Ignored on flat
    #: machines and 1-node clusters.
    node: int | None = None

    def governor_spec(self, n_cpus: int) -> GovernorSpec:
        if self.governor is not None:
            if self.governor.resources != n_cpus:
                # The cluster allocation wins; clamp min_resources (unused
                # by the simulator) so the resize cannot fail validation.
                return replace(
                    self.governor, resources=n_cpus,
                    min_resources=min(self.governor.min_resources, n_cpus))
            return self.governor
        return GovernorSpec(
            resources=n_cpus, policy=self.policy,
            prediction=PredictionConfig(rate_s=self.prediction_rate_s,
                                        min_samples=self.min_samples),
            spin_budget=self.spin_budget, monitoring=self.monitoring,
            power=self.power)


class _SimJob:
    __slots__ = (
        "cluster", "spec", "name", "graph", "bus", "cpus", "governor",
        "monitor", "scheduler", "predictor", "policy", "energy",
        "manager", "sharing", "rate_s", "epoch", "waking", "borrowed",
        "t_done", "monitor_events", "arrivals_pending", "spin_budget",
        "home", "mm", "socket_penalty", "transfers", "transfer_seconds",
        "migrations", "pending_moves", "running", "failed")

    def __init__(self, cluster: "SimCluster", spec: SimJobSpec,
                 cpus: list[int]) -> None:
        self.cluster = cluster
        self.spec = spec
        self.name = spec.name
        self.graph = spec.graph
        cm = cluster.cluster_model
        multi = cluster._multi
        #: home node + home machine: every latency constant and service
        #: time this job pays comes from its own node's machine model
        #: (identical to ``cluster.machine`` on flat/1-node clusters)
        self.home = (spec.node if spec.node is not None
                     else (cm.node_of(cpus[0]) if multi else 0))
        self.mm = cm.nodes[self.home] if multi else cluster.machine
        machine = self.mm
        # A job-private bus is namespaced with the job name, so a trace
        # recorder attached to several jobs' buses yields one combined,
        # per-app-splittable multi-app trace.  An externally provided
        # bus keeps whatever namespace its creator chose.  Multi-node
        # buses additionally stamp the home node (and worker sockets on
        # multi-socket nodes) onto every event.
        if spec.bus is not None:
            self.bus = spec.bus
        elif multi:
            self.bus = EventBus(
                app=spec.name, node=self.home,
                socket_of=(cm.socket_of if any(
                    m.topology().n_sockets > 1 for m in cm.nodes)
                    else None))
        else:
            self.bus = EventBus(app=spec.name)
        gspec = spec.governor_spec(len(cpus))
        if machine.core_types is not None and gspec.topology is None:
            # asymmetric machine: hand the topology to the whole stack
            # (per-type monitoring/energy, speed-aware Δ, park order).
            # A job pinned to a cpu subset gets a *sliced* topology so
            # its power accounting matches the per-core service speeds
            # the machine applies; the id list is grouped by type so the
            # governor's positional mapping lines up with the machine's.
            # Global cpu ids wrap per node on multi-node clusters.
            topo = machine.topology()
            loc = cm.local_id if multi else (lambda c: c)
            if len(cpus) == machine.n_cores:
                gspec = replace(gspec, topology=topo)
            else:
                rank = {t.name: i for i, t in enumerate(topo.types)}
                cpus = sorted(cpus,
                              key=lambda c: (rank[topo.type_of(loc(c))], c))
                counts: dict[str, int] = {}
                for c in cpus:
                    ct = topo.type_of(loc(c))
                    counts[ct] = counts.get(ct, 0) + 1
                sliced = CoreTopology(types=tuple(
                    replace(t, count=counts[t.name])
                    for t in topo.types if t.name in counts))
                gspec = replace(gspec, topology=sliced)
        elif multi and gspec.topology is None:
            # Homogeneous node on a multi-node cluster: hand the stack
            # an explicit single-type topology so borrowed remote cores
            # can be announced under their locality-tier type name
            # ("core@n<k>") and the monitor learns per-tier costs —
            # the hetero machinery on one type reproduces the
            # homogeneous algorithms decision-for-decision.
            gspec = replace(gspec,
                            topology=CoreTopology.homogeneous(len(cpus)))
        self.cpus = cpus
        self.governor = ResourceGovernor(
            gspec, clock=lambda: cluster.now,
            worker_ids=list(cpus), t0=cluster.now, bus=self.bus)
        self.monitor = self.governor.monitor
        self.scheduler = Scheduler(self.monitor, bus=self.bus,
                                   clock=lambda: cluster.now,
                                   threadsafe=cluster.threadsafe)
        self.predictor = self.governor.predictor
        self.policy = self.governor.policy
        self.energy = self.governor.energy
        self.manager = self.governor.manager
        self.sharing = self.governor.sharing
        self.rate_s = self.governor.spec.prediction.rate_s
        self.epoch: dict[int, int] = {c: 0 for c in cpus}
        self.waking: set[int] = set()
        self.borrowed: set[int] = set()
        self.t_done: float | None = None
        self.monitor_events = 0
        #: tasks released over time that have not been submitted yet —
        #: an open job is done only when arrivals are exhausted AND the
        #: scheduler drained.
        self.arrivals_pending = 0
        #: hoisted once — ``getattr(policy, "spin_budget", ...)`` sat on
        #: the per-empty-poll path
        self.spin_budget: int | None = getattr(self.policy, "spin_budget",
                                               None)
        #: home machine's cross-socket dilation, pre-resolved (None when
        #: inert: single-socket node or penalty 1.0 — the common case
        #: pays one attribute load per task start, nothing more)
        self.socket_penalty: float | None = (
            machine.remote_socket_penalty
            if (machine.remote_socket_penalty != 1.0
                and machine.topology().n_sockets > 1) else None)
        self.transfers = 0
        self.transfer_seconds = 0.0
        self.migrations = 0
        #: cores granted to an in-flight migration while EXECUTING; they
        #: move (old → new global id) at their next task boundary
        self.pending_moves: dict[int, int] | None = None
        #: machine conditions only (both stay empty otherwise): the task
        #: each core is executing (so a CORE_FAIL can re-queue it) and
        #: this job's currently failed owned cores (dict, not set — the
        #: determinism lint forbids set iteration)
        self.running: dict[int, Task] = {}
        self.failed: dict[int, bool] = {}

    @property
    def done(self) -> bool:
        return self.arrivals_pending == 0 and self.scheduler.drained()

    def spinning_workers(self) -> list[int]:
        # wake_first order: on heterogeneous machines ready work is
        # dispatched to the fastest spinning cores first (identity order
        # on homogeneous machines)
        return self.manager.spinning(exclude=self.waking)


class SimCluster:
    """Event loop over one machine shared by one or more jobs.

    ``threadsafe=False`` (the default — the event loop is the only
    thread that ever touches the per-job schedulers) selects the
    lock-free sequential :class:`~repro.runtime.scheduler.Scheduler`
    fast path; ``threadsafe=True`` runs the locked reference scheduler
    instead.  Both paths execute the identical decision logic in the
    identical order — ``tests/test_simperf.py`` pins byte-identical
    traces and bit-identical reports across the two for every
    registered policy.
    """

    def __init__(self, machine: MachineModel | ClusterModel,
                 broker: ResourceBroker | None = None,
                 threadsafe: bool = False,
                 conditions: ConditionTimeline | None = None) -> None:
        if isinstance(machine, ClusterModel):
            #: the locality hierarchy; a 1-node cluster takes the flat
            #: single-machine paths end to end (byte parity with the
            #: equivalent MachineModel by construction)
            self.cluster_model: ClusterModel | None = machine
            self._multi = machine.n_nodes > 1
            machine = machine.nodes[0]
        else:
            self.cluster_model = None
            self._multi = False
        self.machine = machine
        self.broker = broker
        self.threadsafe = threadsafe
        self.arbiter: ClusterArbiter | None = None
        if broker is not None:
            if self._multi:
                cm = self.cluster_model
                assert cm is not None
                if (not broker.typed and any(
                        m.core_types is not None for m in cm.nodes)):
                    # asymmetric node(s): per-type pool accounting over
                    # the global core-id space
                    broker.set_core_type_of(cm.type_of)
                self.arbiter = ClusterArbiter(broker, cluster=cm)
            else:
                topo = None
                if machine.core_types is not None:
                    # per-core-type pool accounting: a P-core lent must
                    # not come back as an E-core grant
                    if not broker.typed:
                        broker.set_core_type_of(machine.topology().type_of)
                    topo = machine.topology()
                self.arbiter = ClusterArbiter(broker, topology=topo)
        #: machine-condition timeline + live view.  An EMPTY (or absent)
        #: timeline leaves both None: every conditions gate below stays
        #: closed and the run is byte-identical to the pre-conditions
        #: simulator.
        self.timeline = conditions if conditions else None
        self._cond: MachineConditions | None = (
            MachineConditions(conditions) if conditions else None)
        #: machine-wide power-cap compliance: per-job meters can only
        #: judge their *own* draw against the cap, so a 48 W machine
        #: split between two 24 W tenants would look compliant per
        #: meter.  The drain loop integrates the summed draw across all
        #: jobs against the active cap at every virtual-time advance
        #: (piecewise-constant between events, so this is exact).
        self._machine_cap: float | None = None
        self.machine_cap_violation_s = 0.0
        self.now = 0.0
        #: per-task fast path: homogeneous machines divide service times
        #: by one constant (None on machines with typed cores and on
        #: multi-node clusters, where locality costs are per-task)
        self._flat_speed = (machine.core_speed
                            if machine.core_types is None
                            and not self._multi else None)
        # Flattened heap entries (t, seq, kind, a, b, c, d): pushing one
        # event allocates a single tuple — no nested payload tuple — and
        # the unique seq tiebreak guarantees comparisons never reach the
        # (unorderable) job/task objects behind it.
        self._heap: list[tuple] = []
        self._seq = itertools.count()
        self._next_seq = self._seq.__next__
        self.jobs: dict[str, _SimJob] = {}
        self._undone = 0
        #: events drained by the last :meth:`run` (throughput metric for
        #: ``benchmarks/bench_simperf.py``)
        self.events_processed = 0

    # -- setup ----------------------------------------------------------------

    def add_job(self, spec: SimJobSpec) -> _SimJob:
        cpus = spec.cpus
        if cpus is None:
            if self._multi:
                assert self.cluster_model is not None
                cpus = list(self.cluster_model.cores_of(
                    spec.node if spec.node is not None else 0))
            else:
                base = sum(len(j.cpus) for j in self.jobs.values())
                cpus = list(range(base, base + self.machine.n_cores))
        job = _SimJob(self, spec, list(cpus))
        if self._cond is not None:
            job.governor.attach_conditions(self._cond)
        self.jobs[spec.name] = job
        if self.broker is not None:
            self.broker.register_job(spec.name, list(job.cpus))
            assert self.arbiter is not None
            self.arbiter.register(spec.name, job.governor, node=job.home)
        return job

    def _push(self, t: float, kind: int, a: Any = None, b: Any = None,
              c: Any = None, d: Any = None) -> None:
        _heappush(self._heap, (t, self._next_seq(), kind, a, b, c, d))

    # -- main loop --------------------------------------------------------------

    def run(self, max_events: int = 50_000_000) -> dict[str, SimReport]:
        if self.timeline is not None:
            # Perturbations are scheduled before the t=0 submissions so
            # a time-0 condition is in force before any task starts.
            for p in self.timeline:
                k = p.kind
                if k is PerturbationKind.POWER_CAP:
                    self._push(p.time, _POWER, p)
                elif k is PerturbationKind.THERMAL_THROTTLE:
                    self._push(p.time, _THROTTLE, p)
                else:
                    self._push(p.time, _FAULT, p)
        for job in self.jobs.values():
            self._submit_or_schedule(job)
        for job in self.jobs.values():
            self._dispatch(job)
        for job in self.jobs.values():
            for w in job.spinning_workers():
                self._poll(job, w)
            if job.policy.uses_predictions:
                self._push(self.now + job.rate_s, _TICK, job)
        # Specialized drain loop: heappop and the bound handlers are
        # hoisted into locals, dispatch is a kind-indexed if/elif over
        # ints, and termination is a counter decremented when a job
        # drains (`all(j.done ...)` re-walked every job per event).
        self._undone = sum(1 for j in self.jobs.values() if not j.done)
        events = 0
        heap = self._heap
        pop = heapq.heappop
        on_finish = self._on_finish
        on_tick = self._on_tick
        on_resume = self._on_resume
        on_spin_expire = self._on_spin_expire
        on_arrive = self._on_arrive
        on_fault = self._on_fault
        on_throttle = self._on_throttle
        on_power = self._on_power
        cond_on = self._cond is not None
        cond_jobs = list(self.jobs.values())
        while heap and self._undone:
            events += 1
            if events > max_events:
                raise RuntimeError("simulator exceeded max_events")
            t, _, kind, a, b, c, d = pop(heap)
            if cond_on:
                cap = self._machine_cap
                if cap is not None and t > self.now:
                    # completed jobs are excluded: their runtime has
                    # exited, and their meters froze at the final
                    # (possibly all-spinning) draw
                    watts = 0.0
                    for j in cond_jobs:
                        if j.t_done is None:
                            watts += j.energy.watts
                    if watts > cap + 1e-12:
                        self.machine_cap_violation_s += t - self.now
            self.now = t
            if kind == _FINISH:
                on_finish(a, b, c, d)
            elif kind == _RESUME:
                on_resume(a, b)
            elif kind == _TICK:
                on_tick(a)
            elif kind == _SPIN_EXPIRE:
                on_spin_expire(a, b, c)
            elif kind == _ARRIVE:
                on_arrive(a, b)
            elif kind == _FAULT:
                on_fault(a)
            elif kind == _THROTTLE:
                on_throttle(a)
            else:
                on_power(a)
        self.events_processed = events
        reports = {}
        for job in self.jobs.values():
            if not job.done:
                raise RuntimeError(
                    f"job {job.name!r} deadlocked with "
                    f"{job.scheduler.pending} pending tasks")
            t_end = job.t_done if job.t_done is not None else self.now
            job.energy.finish(t_end)
            reports[job.name] = self._report(job)
        return reports

    def _report(self, job: _SimJob) -> SimReport:
        return job.governor.report(
            name=job.name,
            tasks_fallback=len(job.graph.tasks),
            dlb_calls=(self.broker.job_calls(job.name)
                       if self.broker else 0),
            monitor_events=job.monitor_events,
            sharing=(self.arbiter.stats[job.name].as_dict()
                     if self.arbiter is not None else None),
            node=job.home if self._multi else None,
            transfers=job.transfers,
            transfer_seconds=job.transfer_seconds,
            migrations=job.migrations,
        )

    def _submit_or_schedule(self, job: _SimJob) -> None:
        """Closed tasks go to the scheduler at t=0 (one batched
        ``submit_all``); tasks with a release time (from
        ``spec.arrivals`` or pre-stamped, e.g. by a replayed trace)
        become ``_ARRIVE`` events on the virtual timeline."""
        if job.spec.arrivals is not None:
            job.spec.arrivals.assign(job.graph.tasks)
        now = self.now
        closed = []
        for task in job.graph.tasks:
            rt = task.release_time
            if rt is None or rt <= now:
                closed.append(task)
            else:
                job.arrivals_pending += 1
                self._push(rt, _ARRIVE, job, task)
        if closed:
            job.scheduler.submit_all(closed)

    # -- event handlers -----------------------------------------------------------

    def _on_arrive(self, job: _SimJob, task: Task) -> None:
        job.arrivals_pending -= 1
        if job.bus.interested(EventKind.TASK_ARRIVED):
            job.bus.publish(RuntimeEvent(
                kind=EventKind.TASK_ARRIVED, time=self.now,
                task_id=task.task_id, type_name=task.type_name,
                cost=task.cost))
        became_ready = job.scheduler.submit(task)
        if became_ready:
            self._work_added(job)

    def _on_finish(self, job: _SimJob, cpu: int, task: Task,
                   elapsed: float) -> None:
        if self._cond is not None:
            # Under machine conditions the d slot carries (dur, epoch):
            # a CORE_FAIL mid-task bumped the core's epoch when it
            # re-queued the task, so the dead core's in-flight finish
            # pops here as stale and is dropped.
            elapsed, ep = elapsed
            if job.epoch.get(cpu) != ep:
                return
            job.running.pop(cpu, None)
        # successors consult this for cross-node transfer / cross-socket
        # penalty on the dependency edge; stamp before any dispatch
        task.completed_on = cpu
        job.manager.task_finished(cpu)
        newly = job.scheduler.complete(task, elapsed, worker_id=cpu)
        if job.monitor is not None:
            job.monitor_events += 3  # ready/execute/complete round trip
        # inline job.done (a property + drained() call per finish)
        if job.arrivals_pending == 0 and job.scheduler._pending == 0:
            job.t_done = self.now
            self._undone -= 1
            if self.broker is not None:
                # a finished app claims nothing: drop any fairness
                # reservation its last short acquire registered
                self.broker.register_demand(job.name, 0)
        pm = job.pending_moves
        if pm is not None and cpu in pm:
            # an in-flight migration was waiting for this core's task
            # boundary: move it now instead of polling on the old node
            self._move_core(job, cpu, pm.pop(cpu))
            if newly:
                self._work_added(job)
            return
        if newly:
            self._work_added(job)
        if job.manager.state_of(cpu) is not WorkerState.SPIN:
            # _work_added's instant dispatch already handed this worker a
            # new task (it was spinning the moment the queue refilled).
            return
        # Borrowed CPU honoring a reclaim flag returns at task boundary.
        if (self.broker is not None and cpu in job.borrowed
                and self.broker.cpu_must_return(cpu)):
            self._return_borrowed(job, cpu)
            return
        # LeWI-style eager acquisition happens at every task boundary while
        # ready work remains (this is what makes LeWI's call count explode
        # for fine-grained tasks — paper Table 3).  The call overhead
        # delays this worker's next poll.
        if (job.sharing and job.policy.eager_acquire
                and job.scheduler.ready_count > 0):
            assert self.broker is not None and self.arbiter is not None
            before = self.broker.job_calls(job.name)
            self.arbiter.execute(AppPlan(app=job.name, acquire=1,
                                         eager=True),
                                 lambda c: self._hand_cpu_to(job, c))
            n_calls = self.broker.job_calls(job.name) - before
            if n_calls:
                self._push(self.now + n_calls * job.mm.dlb_call_overhead,
                           _RESUME, job, cpu)
                return
        self._poll(job, cpu)

    def _on_tick(self, job: _SimJob) -> None:
        # inline job.done — this gate runs once per tick
        if job.arrivals_pending == 0 and job.scheduler._pending == 0:
            return  # stop rescheduling; lets the loop terminate
        job.governor.tick()
        # Trim: re-evaluate spinning workers against the fresh Δ, in
        # park order (spinning_workers is wake/dispatch-ordered — using
        # it here would park the fastest cores first).  With ready work
        # queued the loop body is a guaranteed immediate break, so skip
        # building the spinner list at all.
        if job.scheduler.ready_count == 0:
            uniform = job.policy.poll_uniform
            mgr = job.manager
            if not job.sharing and not mgr.park_ordered:
                # Homogeneous non-sharing trim: park order is dict order
                # and decisions can only SPIN (value mutation of the
                # visited key — iteration-safe) or IDLE, so the spinner
                # list need not be materialized.  With a uniform policy
                # the loop typically stops at the very first verdict —
                # this path runs once per tick, the hottest line of
                # tick-dominated sims.
                waking = job.waking
                spin = WorkerState.SPIN
                poll_empty = mgr.poll_empty
                for w, s in mgr.states_items_unlocked():
                    if s is not spin or w in waking:
                        continue
                    decision = poll_empty(w)
                    if decision is PollDecision.SPIN and uniform:
                        break
            else:
                for w in mgr.park_first(job.spinning_workers()):
                    if job.scheduler.ready_count > 0:
                        break
                    decision = mgr.poll_empty(w)
                    if decision is PollDecision.LEND:
                        self._lend(job, w)
                    elif decision is PollDecision.SPIN and uniform:
                        # uniform policies answer SPIN identically for
                        # every remaining spinner (δ unchanged by SPIN)
                        break
        # Grow: resume idle workers / acquire broker CPUs — one call.
        ready = job.scheduler.ready_count
        if ready > 0:
            self._resume_workers(job, job.manager.notify_added(ready))
        if job.sharing:
            # Centralized acquisition: the arbiter peeks DLB's free-CPU
            # counter (cheap shared-memory read, not a DLB call) before
            # paying for an acquisition round-trip, and splits the
            # request per core type on heterogeneous machines.
            assert self.arbiter is not None
            plan = self.arbiter.plan_tick(job.name, job.manager.active,
                                          job.scheduler.ready_count)
            if plan is not None:
                self.arbiter.execute(plan,
                                     lambda c: self._hand_cpu_to(job, c))
        self._push(self.now + job.rate_s, _TICK, job)

    def _on_resume(self, job: _SimJob, cpu: int) -> None:
        job.waking.discard(cpu)
        if job.manager.state_of(cpu) is WorkerState.SPIN:
            self._poll(job, cpu)

    def _on_spin_expire(self, job: _SimJob, cpu: int, epoch: int) -> None:
        if job.epoch.get(cpu) != epoch:
            return  # stale: worker ran a task / changed state meanwhile
        if job.manager.state_of(cpu) is not WorkerState.SPIN:
            return
        if job.scheduler.ready_count > 0:
            return  # work arrived; dispatch already handled it
        budget = job.spin_budget if job.spin_budget is not None else 1
        decision = job.manager.poll_empty(cpu, spin_count_override=budget)
        if decision is PollDecision.LEND:
            self._lend(job, cpu)

    # -- machine-condition handlers -----------------------------------------------

    def _publish_perturbation(self, p: Perturbation) -> None:
        """Record the perturbation as a runtime event (once per distinct
        bus — jobs sharing an external bus must not duplicate it) so
        traces of perturbed runs round-trip through the replayer."""
        seen: dict[int, bool] = {}
        for job in self.jobs.values():
            bus = job.bus
            if id(bus) in seen:
                continue
            seen[id(bus)] = True
            if bus.interested(EventKind.PERTURBATION):
                bus.publish(RuntimeEvent(
                    kind=EventKind.PERTURBATION, time=self.now,
                    data=p.to_dict()))

    def _owner_of(self, cpu: int) -> _SimJob | None:
        for job in self.jobs.values():
            if cpu in job.cpus:
                return job
        return None

    def _note_failed(self, job: _SimJob, cpu: int, failed: bool) -> None:
        if failed:
            job.failed[cpu] = True
        else:
            job.failed.pop(cpu, None)
        job.governor.set_failed_workers(list(job.failed))

    def _on_fault(self, p: Perturbation) -> None:
        cond = self._cond
        assert cond is not None
        cond.apply(p)
        self._publish_perturbation(p)
        if p.kind is PerturbationKind.STRAGGLER:
            # nothing structural: _start dilates subsequent durations on
            # the slow core and the monitor skips its suspect samples
            return
        c = p.core
        assert c is not None
        if p.kind is PerturbationKind.CORE_FAIL:
            # Whoever currently holds the core live (owner or borrower)
            # loses it; an in-flight task is re-queued at the head of
            # the ready queue and re-executed on a surviving core.
            holder = None
            for job in self.jobs.values():
                st = job.manager.state_of(c)
                if st is not None and st is not WorkerState.LENT:
                    holder = job
                    break
            if holder is not None:
                task = holder.running.pop(c, None)
                holder.epoch[c] = holder.epoch.get(c, 0) + 1
                holder.waking.discard(c)
                # closes the core's energy timeline (OFF) from any state
                holder.manager.remove_worker(c)
                holder.borrowed.discard(c)
                if task is not None:
                    holder.scheduler.requeue(task)
            if self.broker is not None:
                self.broker.fail_core(c)
            owner = self._owner_of(c)
            if owner is not None:
                if (owner is not holder
                        and owner.manager.state_of(c) is not None):
                    # the owner kept a LENT registration for a core that
                    # was borrowed out — retire it too
                    owner.epoch[c] = owner.epoch.get(c, 0) + 1
                    owner.manager.remove_worker(c)
                self._note_failed(owner, c, True)
            if holder is not None and holder.scheduler.ready_count > 0:
                self._work_added(holder)
        else:  # CORE_RECOVER
            if self.broker is not None:
                self.broker.recover_core(c)
            owner = self._owner_of(c)
            if owner is None:
                return
            self._note_failed(owner, c, False)
            if owner.t_done is not None:
                return  # job already finished; nothing to resume
            # re-adopt under its true identity (type-correct α/energy),
            # waking after the usual resume latency
            if not self._multi:
                ct = (self.machine.topology().core_type_at(c)
                      if self.machine.core_types is not None else None)
            else:
                cm = self.cluster_model
                assert cm is not None
                src = cm.node_of(c)
                ct = cm.nodes[src].topology().core_type_at(
                    c - cm.base_of(src))
            owner.governor.adopt_worker(c, core_type=ct)
            owner.epoch[c] = owner.epoch.get(c, 0) + 1
            owner.waking.add(c)
            self._push(self.now + owner.mm.resume_latency, _RESUME,
                       owner, c)

    def _on_throttle(self, p: Perturbation) -> None:
        cond = self._cond
        assert cond is not None
        cond.apply(p)
        self._publish_perturbation(p)
        caps = cond.thermal_caps()
        for job in self.jobs.values():
            job.governor.apply_thermal(caps, now=self.now)

    def _on_power(self, p: Perturbation) -> None:
        cond = self._cond
        assert cond is not None
        cond.apply(p)
        self._publish_perturbation(p)
        self._machine_cap = p.watts
        for job in self.jobs.values():
            job.energy.set_power_cap(self.now, p.watts)
        if self.arbiter is not None:
            jobs = self.jobs
            active_w = max(j.energy.power_model.active
                           for j in jobs.values())
            self.arbiter.set_power_cap(
                p.watts,
                current_watts=lambda: sum(j.energy.watts
                                          for j in jobs.values()),
                core_active_w=active_w)

    # -- mechanics ----------------------------------------------------------------

    def _poll(self, job: _SimJob, cpu: int) -> None:
        task = job.scheduler.poll(worker_id=cpu)
        if task is not None:
            self._start(job, cpu, task)
            return
        decision = job.manager.poll_empty(cpu)
        if decision is PollDecision.SPIN:
            budget = job.spin_budget
            if budget is not None:
                job.epoch[cpu] += 1
                self._push(self.now + budget * job.mm.poll_interval,
                           _SPIN_EXPIRE, job, cpu, job.epoch[cpu])
        elif decision is PollDecision.LEND:
            self._lend(job, cpu)
        # IDLE: state transition already applied by the manager.

    def _start(self, job: _SimJob, cpu: int, task: Task) -> None:
        st = task.service_time
        if st is None:
            raise ValueError(
                f"task {task.type_name}#{task.task_id} has no service_time "
                "(required by the simulator)")
        job.epoch[cpu] += 1
        job.manager.task_started(cpu)
        flat = self._flat_speed
        if flat is not None and not job.governor._freq_cache:
            # homogeneous machine, no DVFS plan applied: service_time()
            # would resolve per-core speed and frequency to the same
            # constants on every single task
            dur = st / flat
        elif not self._multi:
            dur = self.machine.service_time(
                st, core=cpu, freq=job.governor.frequency_of(cpu))
            sp = job.socket_penalty
            if sp is not None:
                # cross-socket dependency: the task consumes data its
                # predecessor produced on the other NUMA domain
                topo = self.machine._topology
                sk = topo.socket_of(cpu)
                for dep in task.deps:
                    co = dep.completed_on
                    if co is not None and topo.socket_of(co) != sk:
                        dur *= sp
                        break
        else:
            # Multi-node: service time comes from the executing core's
            # own node, dilated by the remote penalty when that node is
            # not the app's home; cross-node dependency edges charge a
            # network transfer that delays the start but is NOT part of
            # the task's measured elapsed (wire time, not compute time).
            cm = self.cluster_model
            node = cm.node_of(cpu)
            nm = cm.nodes[node]
            dur = nm.service_time(
                st, core=cpu - cm.base_of(node),
                freq=job.governor.frequency_of(cpu))
            if node != job.home:
                dur *= cm.penalty(job.home, node)
            elif job.socket_penalty is not None:
                topo = nm._topology
                base = cm.base_of(node)
                sk = topo.socket_of(cpu - base)
                for dep in task.deps:
                    co = dep.completed_on
                    if (co is not None and cm.node_of(co) == node
                            and topo.socket_of(co - base) != sk):
                        dur *= job.socket_penalty
                        break
            xfer = 0.0
            src = node
            if cm.transfer_latency > 0.0:
                # transfers from several predecessors overlap on the
                # wire: the slowest edge gates the start
                for dep in task.deps:
                    co = dep.completed_on
                    if co is not None:
                        dn = cm.node_of(co)
                        if dn != node:
                            x = cm.transfer_time(dn, node)
                            if x > xfer:
                                xfer, src = x, dn
            if job.monitor is not None:
                dur += 3 * nm.monitor_event_overhead
            if xfer > 0.0:
                job.transfers += 1
                job.transfer_seconds += xfer
                if job.bus.interested(EventKind.TRANSFER):
                    job.bus.publish(RuntimeEvent(
                        kind=EventKind.TRANSFER, time=self.now,
                        task_id=task.task_id, worker_id=cpu,
                        elapsed=xfer,
                        data={"src": src, "dst": node}))
            cond = self._cond
            if cond is not None:
                dur *= cond.slowdown_of(cpu)
                job.running[cpu] = task
                self._push(self.now + xfer + dur, _FINISH, job, cpu, task,
                           (dur, job.epoch[cpu]))
            else:
                self._push(self.now + xfer + dur, _FINISH, job, cpu, task,
                           dur)
            return
        if job.monitor is not None:
            dur += 3 * self.machine.monitor_event_overhead
        cond = self._cond
        if cond is not None:
            # straggling cores silently dilate the task; the monitor
            # marks their samples suspect so α stays clean
            dur *= cond.slowdown_of(cpu)
            job.running[cpu] = task
            self._push(self.now + dur, _FINISH, job, cpu, task,
                       (dur, job.epoch[cpu]))
            return
        self._push(self.now + dur, _FINISH, job, cpu, task, dur)

    def _dispatch(self, job: _SimJob) -> None:
        """Hand ready tasks to spinning workers instantly.

        Spinners are consumed lazily: with R ready tasks only the first
        R spinning workers are ever visited — this loop used to
        re-filter and re-sort the whole state map (plus re-take the
        ready-count lock) once per handed-out task.  ``_start`` only
        flips the dispatched worker's own state, which keeps the lazy
        iteration valid.
        """
        sched = job.scheduler
        if sched.ready_count == 0:
            return
        for w in job.manager.iter_spinning(exclude=job.waking):
            task = sched.poll(worker_id=w)
            if task is None:
                return
            self._start(job, w, task)

    def _work_added(self, job: _SimJob) -> None:
        self._dispatch(job)
        ready = job.scheduler.ready_count
        if ready > 0:
            self._resume_workers(job, job.manager.notify_added(ready))
        if job.sharing:
            assert self.arbiter is not None
            plan = self.arbiter.plan_work_added(job.name,
                                                job.manager.active,
                                                job.scheduler.ready_count)
            if plan is not None:
                self.arbiter.execute(plan,
                                     lambda c: self._hand_cpu_to(job, c))

    def _resume_workers(self, job: _SimJob, woken: list[int]) -> None:
        for w in woken:
            job.waking.add(w)
            self._push(self.now + job.mm.resume_latency, _RESUME,
                       job, w)

    # -- DLB mechanics ---------------------------------------------------------------

    def _lend(self, job: _SimJob, cpu: int) -> None:
        assert self.arbiter is not None
        job.epoch[cpu] = job.epoch.get(cpu, 0) + 1
        was_borrowed = cpu in job.borrowed
        holder = self.arbiter.lend(job.name, cpu)
        if was_borrowed:
            job.borrowed.discard(cpu)
            # remove_worker closes the core's energy timeline (OFF)
            job.manager.remove_worker(cpu)
            if holder:
                self._hand_cpu_to(self.jobs[holder], cpu)
        # Owned CPU stays registered as LENT (energy OFF) in our manager.

    def _return_borrowed(self, job: _SimJob, cpu: int) -> None:
        assert self.arbiter is not None
        owner_name = self.arbiter.return_cpu(job.name, cpu)
        job.borrowed.discard(cpu)
        # remove_worker closes the core's energy timeline (OFF)
        job.manager.remove_worker(cpu)
        self._hand_cpu_to(self.jobs[owner_name], cpu)
        if (job.scheduler.ready_count > 0 and job.manager.active == 0
                and not job.waking):
            # The forced return took the job's LAST worker while work is
            # still queued (possible once ≥3 jobs trade CPUs: every
            # owned CPU lent away, the final borrowed one reclaimed).
            # Policies without a prediction tick (LeWI/hybrid) have no
            # other wake-up path, so this deadlocked N-app clusters:
            # claw capacity back through the broker — own lent CPUs
            # first, a reclaim flag if they are all borrowed out.
            self.arbiter.execute(
                AppPlan(app=job.name, acquire=job.scheduler.ready_count),
                lambda c: self._hand_cpu_to(job, c))

    def _hand_cpu_to(self, job: _SimJob, cpu: int) -> None:
        """CPU (re)arrives at ``job`` after the DLB hand-over latency
        (plus the network transfer when it crosses nodes)."""
        lat = job.mm.borrow_latency
        src = None
        if self._multi:
            cm = self.cluster_model
            src = cm.node_of(cpu)
            if src != job.home:
                lat += cm.transfer_time(src, job.home)
        if job.manager.state_of(cpu) is not None:
            job.manager.reclaim(cpu)
        else:
            job.borrowed.add(cpu)
            # announce the borrowed core's true identity so α_{j,c},
            # energy billing and DVFS lookups use the machine's type,
            # not the job's (possibly sliced) positional mapping
            if not self._multi:
                ct = (self.machine.topology().core_type_at(cpu)
                      if self.machine.core_types is not None else None)
            else:
                # cross-node borrows carry their locality tier in the
                # type name ("P@n1"): the monitor learns a separate
                # (task type × core type × tier) α for remote silicon —
                # its service times include the remote penalty — and
                # compute_plan never confuses it with home-node cores
                nm = self.cluster_model.nodes[src]
                ct = nm.topology().core_type_at(
                    cpu - self.cluster_model.base_of(src))
                if src != job.home:
                    ct = replace(ct, name=f"{ct.name}@n{src}", count=1)
            job.governor.adopt_worker(cpu, core_type=ct)
        job.epoch[cpu] = job.epoch.get(cpu, 0) + 1
        job.waking.add(cpu)
        self._push(self.now + lat, _RESUME, job, cpu)

    # -- whole-app migration -----------------------------------------------------

    def migrate_job(self, name: str, dst: int) -> None:
        """Explicit costed migration verb: move app ``name`` and every
        core it owns to free cores on node ``dst``.

        Each core pays ``migration_latency`` before resuming on the new
        node; cores mid-task move at their next task boundary (the
        cooperative-return discipline borrowed cores already follow).
        The app must be *settled*: no borrowed cores held and none of
        its own cores lent out — migrating IOUs would silently rewrite
        another app's accounting.
        """
        if not self._multi:
            raise ValueError("migrate_job needs a multi-node ClusterModel")
        cm = self.cluster_model
        assert cm is not None
        job = self.jobs[name]
        if dst == job.home:
            return
        if not 0 <= dst < cm.n_nodes:
            raise ValueError(f"node {dst} out of range [0, {cm.n_nodes})")
        if job.borrowed:
            raise ValueError(
                f"cannot migrate {name!r}: holding "
                f"{len(job.borrowed)} borrowed core(s)")
        if any(job.manager.state_of(c) is WorkerState.LENT
               for c in job.cpus):
            raise ValueError(
                f"cannot migrate {name!r}: some of its cores are "
                "lent out through the broker")
        used: set[int] = set()
        for j in self.jobs.values():
            used.update(j.cpus)
        free = [c for c in cm.cores_of(dst) if c not in used]
        if len(free) < len(job.cpus):
            raise ValueError(
                f"node {dst} has {len(free)} free core(s); "
                f"{name!r} needs {len(job.cpus)}")
        mapping = dict(zip(list(job.cpus), free))
        job.home = dst
        job.mm = cm.nodes[dst]
        job.socket_penalty = (
            job.mm.remote_socket_penalty
            if (job.mm.remote_socket_penalty != 1.0
                and job.mm.topology().n_sockets > 1) else None)
        job.migrations += 1
        job.bus.node = dst   # subsequent events carry the new home
        if self.arbiter is not None:
            self.arbiter.note_migration(name, dst)
        for old, new in mapping.items():
            if job.manager.state_of(old) is WorkerState.ACTIVE:
                if job.pending_moves is None:
                    job.pending_moves = {}
                job.pending_moves[old] = new
            else:
                self._move_core(job, old, new)

    def _move_core(self, job: _SimJob, old: int, new: int) -> None:
        """Re-home one owned core: retire ``old`` (its energy timeline
        closes OFF) and bring up ``new`` on the destination node after
        ``migration_latency``."""
        cm = self.cluster_model
        assert cm is not None
        job.epoch.pop(old, None)
        job.waking.discard(old)
        job.manager.remove_worker(old)
        job.cpus[job.cpus.index(old)] = new
        if self.broker is not None:
            self.broker.reassign_core(job.name, old, new)
        # always announce the type (for homogeneous nodes the synthetic
        # "core" type matches the injected job topology), so the new
        # worker's α/energy/park accounting lands under the right name
        ct = job.mm.topology().core_type_at(new - cm.base_of(job.home))
        job.governor.adopt_worker(new, core_type=ct)
        job.epoch[new] = job.epoch.get(new, 0) + 1
        job.waking.add(new)
        self._push(self.now + cm.migration_latency, _RESUME, job, new)


class SimExecutor:
    """Convenience wrapper: run ONE task graph under ONE policy.

    Reusable: each :meth:`run` builds a fresh per-run job spec with
    :func:`dataclasses.replace`, so no state (graph, arrivals) leaks
    across runs.  ``self.bus`` is stable across runs — attach a
    :class:`~repro.trace.TraceRecorder` to it before calling :meth:`run`.

    ``threadsafe=False`` (default) runs the lock-free sequential
    scheduler fast path; pass ``threadsafe=True`` for the locked
    reference (observationally identical — see README "Performance").
    ``self.last_events_processed`` records the event count of the last
    run (the throughput benchmarks' denominator).
    """

    def __init__(self, machine: MachineModel, policy: str = "busy",
                 n_cpus: int | None = None, monitoring: bool | None = None,
                 prediction_rate_s: float = DEFAULT_PREDICTION_RATE_S,
                 spin_budget: int = 100,
                 min_samples: int = DEFAULT_MIN_SAMPLES,
                 power: PowerModel | None = None,
                 spec: GovernorSpec | None = None,
                 bus: EventBus | None = None,
                 threadsafe: bool = False,
                 conditions: ConditionTimeline | None = None) -> None:
        self.machine = machine
        self.threadsafe = threadsafe
        self.conditions = conditions
        self.last_events_processed = 0
        self.bus = bus if bus is not None else EventBus()
        if spec is not None:
            self.spec = SimJobSpec(name="job0", graph=TaskGraph(),
                                   cpus=list(range(spec.resources)),
                                   governor=spec, bus=self.bus)
        else:
            self.spec = SimJobSpec(
                name="job0", graph=TaskGraph(), policy=policy,
                cpus=list(range(n_cpus if n_cpus is not None
                                else machine.n_cores)),
                monitoring=monitoring, prediction_rate_s=prediction_rate_s,
                spin_budget=spin_budget, min_samples=min_samples,
                power=power, bus=self.bus)

    def run(self, graph: TaskGraph,
            arrivals: ArrivalProcess | None = None) -> SimReport:
        spec = replace(self.spec, graph=graph,
                       arrivals=(arrivals if arrivals is not None
                                 else self.spec.arrivals))
        cluster = SimCluster(self.machine, threadsafe=self.threadsafe,
                             conditions=self.conditions)
        cluster.add_job(spec)
        try:
            return cluster.run()[spec.name]
        finally:
            self.last_events_processed = cluster.events_processed
