"""Machine models for the discrete-event simulator.

The paper evaluates on two systems (Table 1): MN4 (2×24-core Skylake,
2.10 GHz) and KNL (64-core Knights Landing, 1.30 GHz).  This container has
one physical core, so the policy dynamics are reproduced in *virtual time*
with these models.  ``core_speed`` rescales task service times (KNL cores
are slower per-core: lower frequency, narrower OoO core — we use the
frequency ratio 1.30/2.10 ≈ 0.62 as the first-order factor).

``resume_latency`` is the idle→running wakeup cost (futex wake + context
switch, O(µs)) that makes *idle* policies expensive for fine-grained tasks;
``poll_interval`` is the virtual duration of one empty scheduler poll
(subscription-lock acquire + queue check); ``monitor_event_overhead`` is
charged per monitoring event when the monitoring infrastructure is enabled
(the paper measures ≤3 % total — see ``benchmarks/bench_overhead.py``).
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["MachineModel", "MN4", "KNL"]


@dataclass(frozen=True)
class MachineModel:
    name: str
    n_cores: int
    core_speed: float = 1.0          # task speed relative to an MN4 core
    resume_latency: float = 5e-6     # idle→running (futex + switch)
    poll_interval: float = 5e-7      # one empty poll
    borrow_latency: float = 2e-6     # DLB CPU hand-over
    dlb_call_overhead: float = 1e-6  # one DLB library call (paper §3.3:
    #                                  "such calls do not come for free")
    monitor_event_overhead: float = 5e-8  # per monitoring event

    def service_time(self, base: float) -> float:
        return base / self.core_speed


MN4 = MachineModel(name="MN4", n_cores=48, core_speed=1.0)
KNL = MachineModel(name="KNL", n_cores=64, core_speed=0.62)
