"""Machine models for the discrete-event simulator.

The paper evaluates on two systems (Table 1): MN4 (2×24-core Skylake,
2.10 GHz) and KNL (64-core Knights Landing, 1.30 GHz).  This container has
one physical core, so the policy dynamics are reproduced in *virtual time*
with these models.  ``core_speed`` rescales task service times (KNL cores
are slower per-core: lower frequency, narrower OoO core — we use the
frequency ratio 1.30/2.10 ≈ 0.62 as the first-order factor).

Heterogeneous machines are described by ``core_types`` — an ordered
tuple of :class:`~repro.core.topology.CoreType` (count, relative speed,
per-state power, DVFS steps).  Cores are numbered positionally: the
first type owns indices ``[0, count)``, and so on.  Two asymmetric
presets ship alongside the paper's homogeneous machines:

* :data:`HYBRID_PE` — an Alder-Lake-style hybrid: 8 fast P-cores plus
  16 slower, lower-power E-cores (big.LITTLE economics);
* :data:`DVFS2` — a 2-socket symmetric machine whose sockets can be
  independently re-clocked to 75% / 87.5% / 100% of base frequency.

``resume_latency`` is the idle→running wakeup cost (futex wake + context
switch, O(µs)) that makes *idle* policies expensive for fine-grained tasks;
``poll_interval`` is the virtual duration of one empty scheduler poll
(subscription-lock acquire + queue check); ``monitor_event_overhead`` is
charged per monitoring event when the monitoring infrastructure is enabled
(the paper measures ≤3 % total — see ``benchmarks/bench_overhead.py``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Mapping

from ..core.energy import PowerModel
from ..core.topology import CoreTopology, CoreType

__all__ = ["MachineModel", "MN4", "KNL", "HYBRID_PE", "DVFS2"]


@dataclass(frozen=True)
class MachineModel:
    name: str
    n_cores: int
    core_speed: float = 1.0          # task speed relative to an MN4 core
    resume_latency: float = 5e-6     # idle→running (futex + switch)
    poll_interval: float = 5e-7      # one empty poll
    borrow_latency: float = 2e-6     # DLB CPU hand-over
    dlb_call_overhead: float = 1e-6  # one DLB library call (paper §3.3:
    #                                  "such calls do not come for free")
    monitor_event_overhead: float = 5e-8  # per monitoring event
    #: asymmetric core description; None ⇒ homogeneous (all cores equal)
    core_types: tuple[CoreType, ...] | None = None
    #: service-time dilation for a task whose predecessors completed on
    #: a *different socket* of this machine (remote-NUMA access on the
    #: data it consumes).  1.0 (default) = no penalty — single-socket
    #: machines and every pre-hierarchy model are unaffected; only
    #: multi-socket topologies (``CoreType.socket``) can trigger it.
    remote_socket_penalty: float = 1.0

    def __post_init__(self) -> None:
        if self.remote_socket_penalty < 1.0:
            raise ValueError(
                f"remote_socket_penalty must be >= 1.0, "
                f"got {self.remote_socket_penalty}")
        if self.core_types is not None:
            total = sum(t.count for t in self.core_types)
            if total != self.n_cores:
                raise ValueError(
                    f"core_types counts sum to {total}, "
                    f"but n_cores is {self.n_cores}")
        # Cache the topology once: service_time() sits on the simulator's
        # per-task hot path and must not rebuild/re-validate it.
        topo = (CoreTopology(types=self.core_types)
                if self.core_types is not None
                else CoreTopology.homogeneous(self.n_cores))
        object.__setattr__(self, "_topology", topo)

    def topology(self) -> CoreTopology:
        """The machine's :class:`CoreTopology` (synthesized single-type
        for homogeneous machines — hetero-aware code needs no branch)."""
        return self._topology

    def speed_of(self, core: int | None = None) -> float:
        """Absolute speed of ``core`` (global simulator ids wrap per
        machine); None ⇒ the machine's reference speed."""
        if core is None or self.core_types is None:
            return self.core_speed
        return self.core_speed * self._topology.speed_of(core)

    def service_time(self, base: float, core: int | None = None,
                     freq: float = 1.0) -> float:
        """Wall seconds for ``base`` reference-seconds of work on
        ``core`` at DVFS step ``freq``.

        Contract: ``freq`` is validated against the core type's DVFS
        steps instead of silently extrapolating.  Above the type's top
        step it clamps to ``max_freq`` (requesting a frequency the
        silicon lacks runs at the fastest it has); nonpositive values
        clamp to the *lowest* step (a frequency of zero would stall the
        task forever).  Frequencies inside ``(0, max_freq]`` are
        honored bit-identically even when they sit between or below the
        published steps — thermal throttling legitimately pins a core
        under its slowest nominal step.
        """
        if freq > 1.0 or freq <= 0.0:
            # Every CoreType validates its steps inside (0, 1], so
            # in-band requests (the overwhelmingly common case) skip
            # the typed lookup entirely.
            ct = self._topology.core_type_at(core if core is not None
                                            else 0)
            freq = ct.max_freq if freq > 1.0 else ct.freq_steps[0]
        elif freq != 1.0 and core is not None \
                and self.core_types is not None:
            mf = self._topology.core_type_at(core).max_freq
            if freq > mf:
                freq = mf
        return base / (self.speed_of(core) * freq)

    # -- serialization (ClusterModel round-trip) ----------------------------

    def to_dict(self) -> dict[str, Any]:
        d: dict[str, Any] = {
            "name": self.name, "n_cores": self.n_cores,
            "core_speed": self.core_speed,
            "resume_latency": self.resume_latency,
            "poll_interval": self.poll_interval,
            "borrow_latency": self.borrow_latency,
            "dlb_call_overhead": self.dlb_call_overhead,
            "monitor_event_overhead": self.monitor_event_overhead,
        }
        if self.core_types is not None:
            d["core_types"] = [t.to_dict() for t in self.core_types]
        if self.remote_socket_penalty != 1.0:
            d["remote_socket_penalty"] = self.remote_socket_penalty
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "MachineModel":
        d = dict(d)
        if d.get("core_types") is not None:
            d["core_types"] = tuple(CoreType.from_dict(t)
                                    for t in d["core_types"])
        return cls(**d)


MN4 = MachineModel(name="MN4", n_cores=48, core_speed=1.0)
KNL = MachineModel(name="KNL", n_cores=64, core_speed=0.62)

#: P+E hybrid: 8 performance cores + 16 efficiency cores at ~55% speed
#: and ~40% power — the asymmetric-silicon scenario the homogeneous
#: ``core_speed`` scalar cannot express.
HYBRID_PE = MachineModel(
    name="HYBRID-PE", n_cores=24,
    core_types=(
        CoreType(name="P", count=8, speed=1.0),
        CoreType(name="E", count=16, speed=0.55,
                 power=PowerModel(active=0.4, spin=0.4, idle=0.05)),
    ))

#: Two symmetric sockets with independent DVFS domains (steps as
#: fractions of base frequency) — the frequency-aware predictor may
#: stretch a lightly-loaded socket to a lower step to cut EDP.
DVFS2 = MachineModel(
    name="DVFS2", n_cores=48,
    core_types=(
        CoreType(name="S0", count=24, freq_steps=(0.75, 0.875, 1.0),
                 socket=0),
        CoreType(name="S1", count=24, freq_steps=(0.75, 0.875, 1.0),
                 socket=1),
    ))
