"""Multi-node cluster model — the top tier of the locality hierarchy.

The locality hierarchy is core → socket/NUMA domain → node:

* cores and sockets live inside one :class:`~repro.runtime.machine
  .MachineModel` (``CoreType.socket`` + ``remote_socket_penalty``);
* :class:`ClusterModel` composes N machines into one address space of
  global core ids with a **distance matrix** between nodes.

Distance drives two costs, mirroring how Myrmics (arXiv:1606.04282) and
the distributed-manager OmpSs runtime charge hierarchy crossings:

* ``penalty(home, node)`` — service-time dilation for an app executing
  on a core *remote from its home node* (``1 + remote_penalty · d``):
  borrowed remote silicon is slower for you than for its owner;
* ``transfer_time(src, dst)`` — inter-node network transfer charged
  when a task's predecessors completed on another node
  (``transfer_latency · d``); the simulator emits a ``TRANSFER`` event
  and delays the task start, but the transfer is *not* part of the
  task's measured ``elapsed`` (it is wire time, not compute time).

Global core ids are contiguous per node: node ``k`` owns
``[base_of(k), base_of(k) + nodes[k].n_cores)``.  A flat
:class:`MachineModel` is exactly :meth:`ClusterModel.single` — one
node, zero distances — and every simulator/broker/arbiter code path
reduces to the pre-cluster behaviour on it by construction (pinned
byte-identical in ``tests/test_cluster.py``).
"""

from __future__ import annotations

from bisect import bisect_right
from dataclasses import dataclass, field, replace
from typing import Any, Mapping

from .machine import MachineModel

__all__ = ["ClusterModel"]


@dataclass(frozen=True)
class ClusterModel:
    """N machines + a symmetric inter-node distance matrix."""

    nodes: tuple[MachineModel, ...]
    #: symmetric, zero-diagonal, non-negative; None ⇒ unit distance
    #: between every pair of distinct nodes
    distance: tuple[tuple[float, ...], ...] | None = None
    #: seconds of network transfer per unit distance, charged when a
    #: task's predecessors completed on another node (0 disables)
    transfer_latency: float = 20e-6
    #: service-time dilation per unit distance for an app running on a
    #: core remote from its home node: factor = 1 + remote_penalty · d
    remote_penalty: float = 0.15
    #: per-core cost of an explicit whole-app migration verb
    migration_latency: float = 200e-6
    name: str = "cluster"
    _bases: tuple[int, ...] = field(init=False, repr=False, compare=False)

    def __post_init__(self) -> None:
        if not self.nodes:
            raise ValueError("cluster needs at least one node")
        nodes = tuple(self.nodes)
        n = len(nodes)
        dist = self.distance
        if dist is None:
            dist = tuple(tuple(0.0 if i == j else 1.0 for j in range(n))
                         for i in range(n))
        else:
            dist = tuple(tuple(float(x) for x in row) for row in dist)
            if len(dist) != n or any(len(row) != n for row in dist):
                raise ValueError(
                    f"distance matrix must be {n}x{n} for {n} node(s)")
            for i in range(n):
                if dist[i][i] != 0.0:
                    raise ValueError(
                        f"distance[{i}][{i}] must be 0, got {dist[i][i]}")
                for j in range(n):
                    if dist[i][j] < 0:
                        raise ValueError(
                            f"distance[{i}][{j}] must be >= 0")
                    if dist[i][j] != dist[j][i]:
                        raise ValueError(
                            f"distance matrix must be symmetric: "
                            f"[{i}][{j}]={dist[i][j]} != "
                            f"[{j}][{i}]={dist[j][i]}")
        if self.transfer_latency < 0:
            raise ValueError("transfer_latency must be >= 0")
        if self.remote_penalty < 0:
            raise ValueError("remote_penalty must be >= 0")
        if self.migration_latency < 0:
            raise ValueError("migration_latency must be >= 0")
        bases = []
        base = 0
        for m in nodes:
            bases.append(base)
            base += m.n_cores
        object.__setattr__(self, "nodes", nodes)
        object.__setattr__(self, "distance", dist)
        object.__setattr__(self, "_bases", tuple(bases))

    # -- construction helpers ----------------------------------------------

    @classmethod
    def single(cls, machine: MachineModel) -> "ClusterModel":
        """The trivial 1-node cluster ≡ the flat machine (the simulator
        reproduces the flat path byte-for-byte on it)."""
        return cls(nodes=(machine,), name=machine.name)

    @classmethod
    def symmetric(cls, machine: MachineModel, n_nodes: int,
                  **kwargs: Any) -> "ClusterModel":
        """``n_nodes`` identical machines at unit pairwise distance."""
        return cls(nodes=(machine,) * n_nodes,
                   name=kwargs.pop("name", f"{machine.name}x{n_nodes}"),
                   **kwargs)

    def replay_model(self) -> "ClusterModel":
        """A cluster for byte-exact sim→sim trace replay: node machines
        are neutralized (recorded durations already include core speed,
        monitoring overhead AND locality penalties, so none may be
        re-charged) while distances/transfer latencies are kept — the
        replayed run re-derives identical cross-node ``TRANSFER``
        delays from identical dispatch decisions."""
        from ..trace.replay import TraceReplayer

        return replace(
            self, remote_penalty=0.0,
            nodes=tuple(
                replace(TraceReplayer.replay_machine(m),
                        remote_socket_penalty=1.0)
                for m in self.nodes))

    # -- the global-id address space ----------------------------------------

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def n_cores(self) -> int:
        return self._bases[-1] + self.nodes[-1].n_cores

    def base_of(self, node: int) -> int:
        return self._bases[node]

    def cores_of(self, node: int) -> range:
        """Global core ids owned by ``node``."""
        base = self._bases[node]
        return range(base, base + self.nodes[node].n_cores)

    def node_of(self, core: int) -> int:
        """Node owning global core id ``core`` — every core maps to
        exactly one node."""
        if not 0 <= core < self.n_cores:
            raise IndexError(f"global core id {core} out of range "
                             f"[0, {self.n_cores})")
        return bisect_right(self._bases, core) - 1

    def local_id(self, core: int) -> int:
        return core - self._bases[self.node_of(core)]

    def machine_of(self, core: int) -> MachineModel:
        return self.nodes[self.node_of(core)]

    def socket_of(self, core: int) -> int:
        """Socket of global core id ``core`` within its node."""
        node = self.node_of(core)
        return self.nodes[node].topology().socket_of(
            core - self._bases[node])

    def type_of(self, core: int) -> str:
        """Core-type name of global core id ``core`` (the broker's
        per-type pool accounting on mixed-node clusters)."""
        node = self.node_of(core)
        return self.nodes[node].topology().type_of(
            core - self._bases[node])

    def speed_of(self, core: int) -> float:
        """Absolute speed of global core id ``core`` on its own node
        (before any remote penalty)."""
        node = self.node_of(core)
        return self.nodes[node].speed_of(core - self._bases[node])

    # -- locality costs ------------------------------------------------------

    def penalty(self, home: int, node: int) -> float:
        """Service-time factor for a home-``home`` app executing on a
        ``node`` core (1.0 at home)."""
        return 1.0 + self.remote_penalty * self.distance[home][node]

    def transfer_time(self, src: int, dst: int) -> float:
        """Network delay for a dependency edge crossing src → dst."""
        return self.transfer_latency * self.distance[src][dst]

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict[str, Any]:
        return {
            "name": self.name,
            "nodes": [m.to_dict() for m in self.nodes],
            "distance": [list(row) for row in self.distance],
            "transfer_latency": self.transfer_latency,
            "remote_penalty": self.remote_penalty,
            "migration_latency": self.migration_latency,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ClusterModel":
        d = dict(d)
        d["nodes"] = tuple(MachineModel.from_dict(m) for m in d["nodes"])
        if d.get("distance") is not None:
            d["distance"] = tuple(tuple(row) for row in d["distance"])
        return cls(**d)
