"""Sharded ready queues for the real-thread executor (the fast lane).

:class:`ShardedScheduler` is the multi-threaded hot-path twin of
:class:`~repro.runtime.scheduler.Scheduler`: same submit/poll/complete
contract, same monitor wiring, same lifecycle events — but the single
ready deque + one-lock-per-transition discipline is replaced by the
structure Myrmics-style runtimes use once centralized queue access stops
scaling:

* **per-worker shards** — each worker owns a deque it pushes and pops
  **LIFO** (a completed task's successors run next on the same worker,
  cache-warm, with zero lock traffic);
* **work stealing** — a worker whose shard is empty first drains the
  **global queue** (external submissions / cross-shard handoff), then
  steals **FIFO** from a victim chosen by scan order starting at its own
  id + 1 (stealing the oldest entry takes the work its owner is
  furthest from running);
* **batched monitoring** — workers buffer their monitor transitions
  locally and flush whole batches through
  :meth:`~repro.core.monitoring.TaskMonitor.flush_ops` (one monitor lock
  acquisition per ~``flush_batch`` transitions instead of one each);
* **per-stream event sequencing** — every published lifecycle event is
  stamped with a monotonic per-stream ``seq`` (one stream per worker,
  one for the submit side), so
  :meth:`~repro.trace.TraceRecorder.merged_events` can reconstruct the
  canonical order at flush time and a threaded trace stays replayable.

Why the shards need no lock: CPython's deque ``append`` / ``pop`` /
``popleft`` are single-bytecode-atomic under the GIL, so owner (LIFO
end) and thieves (FIFO end) never corrupt the structure; an
``IndexError`` on a racing pop is the miss signal, not an error.  The
one lock (``_lock``) guards only the *dependency bookkeeping* —
``_pending``, ``task.unmet`` / ``task.successors`` / ``task.done``
wiring — where a lost update would wedge the graph: ``unmet -= 1`` is
three bytecodes and genuinely races without it.

Accepted (and bounded) relaxations versus the single-lock scheduler:

* monitor aggregates may transiently observe a stolen successor's
  *execute* before the completion that readied it (different workers'
  buffers flush independently); the aggregates are sums/EMAs, so totals
  converge exactly and the skew is bounded by ``flush_batch``;
* ``ready_count`` sums deque lengths without a lock — a heuristic input
  (wake decisions, anti-starvation ticks), never a termination signal;
  ``drained()`` reads ``_pending`` under the lock.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Iterable

from ..analysis import guarded_by, single_writer
from ..core.events import QUIET_INTEREST as _QUIET
from ..core.events import EventBus, EventKind, RuntimeEvent
from ..core.monitoring import OP_COMPLETE, OP_EXECUTE, TaskMonitor
from .task import Task

__all__ = ["ShardedScheduler"]

#: monitor transitions buffered per worker before a flush (a task
#: contributes two: execute + complete) — large enough to amortize the
#: monitor lock, small enough that prediction ticks (≥ 1 ms apart) see
#: near-fresh workload totals at real task rates
DEFAULT_FLUSH_BATCH = 32


@single_writer("ops", "seq", "steals")
class _WorkerShard:
    """One worker's slice of the scheduler: its ready deque, its monitor
    op buffer, and its event-stream counters.

    ``ops``/``seq``/``steals`` are single-writer (the owning worker;
    ``flush_all`` touches ``ops`` only after the workers are joined).
    ``queue`` is deliberately *not* declared single-writer: the owner
    pushes/pops the LIFO end while thieves pop the FIFO end — safe
    because each access is one atomic deque operation, never a
    read-modify-write.
    """

    __slots__ = ("queue", "ops", "seq", "steals")

    def __init__(self) -> None:
        self.queue: deque[Task] = deque()
        self.ops: list[tuple] = []
        self.seq = 0
        self.steals = 0


@guarded_by("_pending", "_seq_submit")
class ShardedScheduler:
    """Work-stealing ready-queue scheduler for N real worker threads."""

    def __init__(self, n_workers: int, monitor: TaskMonitor | None = None,
                 bus: EventBus | None = None,
                 clock: Callable[[], float] | None = None,
                 flush_batch: int = DEFAULT_FLUSH_BATCH) -> None:
        if n_workers < 1:
            raise ValueError("need at least one worker shard")
        if flush_batch < 1:
            raise ValueError("flush_batch must be >= 1")
        self.bus = bus if bus is not None else EventBus()
        self.clock = clock if clock is not None else (lambda: 0.0)
        self.monitor = monitor
        if monitor is not None:
            # Same direct-drive absorption as Scheduler: a monitor
            # subscription on this bus would double-count every
            # lifecycle event the buffers already deliver.
            monitor.unsubscribe(self.bus)
            monitor.mark_direct_driven(self.bus)
        self.flush_batch = flush_batch
        self._lock = threading.Lock()
        self._shards = [_WorkerShard() for _ in range(n_workers)]
        #: external submissions + any ready task no worker owns yet;
        #: workers drain it FIFO before stealing
        self._global: deque[Task] = deque()
        self._pending = 0          # submitted, not yet completed
        self._seq_submit = 0       # submit-side event stream counter

    # -- events ----------------------------------------------------------

    def _publish_submit(self, kind: EventKind, task: Task) -> None:  # analysis: caller-locks
        """Submit-side publish, sequenced under ``_lock`` (concurrent
        submitters share the one submit stream)."""
        if not self.bus.interested(kind):
            return
        if kind is EventKind.TASK_SUBMITTED:
            data = {"deps": [d.task_id for d in task.deps],
                    "parent": task.parent.task_id if task.parent else None,
                    "release_time": task.release_time}
        else:
            data = {}
        seq = self._seq_submit
        self._seq_submit = seq + 1
        self.bus.publish(RuntimeEvent(
            kind=kind, time=self.clock(), task_id=task.task_id,
            type_name=task.type_name, cost=task.cost, seq=seq, data=data))

    def _publish_worker(self, kind: EventKind, task: Task,
                        shard: _WorkerShard, worker_id: int,
                        elapsed: float | None = None) -> None:
        """Worker-side publish, sequenced from the worker's own stream
        counter (single-writer — no lock needed)."""
        if not self.bus.interested(kind):
            return
        if kind is EventKind.TASK_COMPLETED:
            data = {"parent": task.parent.task_id if task.parent else None}
        else:
            data = {}
        seq = shard.seq
        shard.seq = seq + 1
        self.bus.publish(RuntimeEvent(
            kind=kind, time=self.clock(), task_id=task.task_id,
            type_name=task.type_name, cost=task.cost, worker_id=worker_id,
            elapsed=elapsed, seq=seq, data=data))

    # -- submission ------------------------------------------------------

    def submit(self, task: Task) -> bool:
        """Register a task; returns True if it became ready immediately."""
        return bool(self._submit_batch((task,)))

    def submit_all(self, tasks: Iterable[Task]) -> int:
        """Submit many tasks; returns how many became ready."""
        return len(self._submit_batch(tasks))

    def _submit_batch(self, tasks: Iterable[Task]) -> list[Task]:
        """Wire dependencies under the lock; expose the ready ones on the
        global queue only *after* their monitor readies are recorded, so
        no worker can execute a task the monitor never saw enter."""
        quiet = self.bus.interest == _QUIET
        ready: list[Task] = []
        with self._lock:
            for task in tasks:
                self._pending += 1
                unmet = 0
                for d in task.deps:
                    if not d.done:
                        unmet += 1
                        d.successors.append(task)
                task.unmet = unmet
                if not quiet:
                    self._publish_submit(EventKind.TASK_SUBMITTED, task)
                if unmet == 0:
                    ready.append(task)
                    if not quiet:
                        self._publish_submit(EventKind.TASK_READY, task)
        if ready:
            monitor = self.monitor
            if monitor is not None:
                monitor.ready_batch(ready)
            self._global.extend(ready)
        return ready

    # -- polling ---------------------------------------------------------

    def poll(self, worker_id: int) -> Task | None:
        """Pop the next task for ``worker_id``: own shard LIFO, then the
        global queue, then steal.  Lock-free on every path.

        Every probe is length-checked before the pop: spinning workers
        call this millions of times against empty queues, and a raised
        ``IndexError`` costs ~20× the truth test.  The check can go
        stale (a thief drains the queue between test and pop), so the
        pop still catches — the exception is the rare race, not the
        common miss.
        """
        shard = self._shards[worker_id]
        task = None
        q = shard.queue
        if q:
            try:
                task = q.pop()
            except IndexError:
                pass
        if task is None:
            task = self._poll_cold(worker_id, shard)
            if task is None:
                return None
        if self.monitor is not None:
            ops = shard.ops
            ops.append((OP_EXECUTE, task.task_id, task.type_name, task.cost))
            if len(ops) >= self.flush_batch:
                self._flush(shard)
        if self.bus.interest != _QUIET:
            self._publish_worker(EventKind.TASK_EXECUTE, task, shard,
                                 worker_id)
        return task

    def _poll_cold(self, worker_id: int,
                   shard: _WorkerShard) -> Task | None:
        g = self._global
        if g:
            try:
                return g.popleft()
            except IndexError:
                pass
        shards = self._shards
        n = len(shards)
        for i in range(1, n):
            vq = shards[(worker_id + i) % n].queue
            if vq:
                try:
                    task = vq.popleft()
                except IndexError:
                    continue
                shard.steals += 1
                return task
        return None

    def complete(self, task: Task, elapsed: float,
                 worker_id: int) -> list[Task]:
        """Mark done; returns tasks that *became ready* as a result.

        Newly-ready successors are pushed onto the completer's own shard
        (LIFO — they run next, cache-warm) *after* their READY events are
        published, so a thief can never record an EXECUTE that precedes
        the READY in wall time.
        """
        with self._lock:
            task.done = True
            self._pending -= 1
            newly_ready: list[Task] = []
            for s in task.successors:
                s.unmet -= 1
                if s.unmet == 0:
                    newly_ready.append(s)
        shard = self._shards[worker_id]
        if self.monitor is not None:
            ops = shard.ops
            ops.append((OP_COMPLETE, task, elapsed, worker_id,
                        task.parent.task_id if task.parent else None,
                        newly_ready))
            if len(ops) >= self.flush_batch:
                self._flush(shard)
        if self.bus.interest != _QUIET:
            for s in newly_ready:
                self._publish_worker(EventKind.TASK_READY, s, shard,
                                     worker_id)
            self._publish_worker(EventKind.TASK_COMPLETED, task, shard,
                                 worker_id, elapsed=elapsed)
        if newly_ready:
            shard.queue.extend(newly_ready)
        return newly_ready

    # -- monitor flushing ------------------------------------------------

    def _flush(self, shard: _WorkerShard) -> None:
        ops = shard.ops
        shard.ops = []
        self.monitor.flush_ops(ops)

    def flush_worker(self, worker_id: int) -> None:
        """Drain this worker's monitor buffer (no-op when empty) — called
        on every empty poll, so an out-of-work worker's last transitions
        reach the monitor before it spins or parks."""
        if self.monitor is None:
            return
        shard = self._shards[worker_id]
        if shard.ops:
            self._flush(shard)

    def flush_all(self) -> None:
        """Backstop drain of every buffer.  Single-threaded callers only
        (``close()`` after joining the workers): ``ops`` buffers are
        single-writer and must not be flushed out from under a live
        owner."""
        if self.monitor is None:
            return
        for shard in self._shards:
            if shard.ops:
                self._flush(shard)

    # -- state -----------------------------------------------------------

    @property
    def ready_count(self) -> int:
        """Approximate ready-task count (lock-free deque length sums) —
        a wake-heuristic input, not a termination signal."""
        n = len(self._global)
        for s in self._shards:
            n += len(s.queue)
        return n

    @property
    def pending(self) -> int:
        with self._lock:
            return self._pending

    @property
    def steals(self) -> int:
        """Total successful steals across all workers (observability)."""
        return sum(s.steals for s in self._shards)

    def drained(self) -> bool:
        with self._lock:
            return self._pending == 0
