"""Real threaded executor.

Runs task graphs with actual Python threads — the correctness twin of the
simulator (same governor-assembled WorkerManager / Policy / TaskMonitor
objects).  Python's GIL means no true parallel speedup on this host; the
executor exists to validate the concurrency logic (locking, idle/resume
protocol, monitor event ordering) under real preemption, and to measure
the *real* bookkeeping overhead of the monitoring infrastructure
(``benchmarks/bench_overhead.py`` / ``bench_threadperf.py``).

The whole resource stack is declared by a
:class:`~repro.core.governor.GovernorSpec` and assembled by
:class:`~repro.core.governor.ResourceGovernor`; the executor owns the
threads, the per-worker wake events and the scheduler.

Hot-path structure (the PR-5 discipline on real threads):

* ready queues are **sharded per worker** with work stealing
  (:class:`~repro.runtime.sharded.ShardedScheduler`) — poll and the
  successor handoff are lock-free;
* monitor updates are **buffered per worker** and flushed in batches
  (one ``TaskMonitor`` lock acquisition per ~32 transitions);
* idle workers park on a **per-worker** ``threading.Event`` and are
  woken *individually* by the manager's targeted waker — no
  ``notify_all`` broadcast, no 50 ms wake-poll;
* the spin loop of a never-idling policy (``busy``) skips the per-poll
  manager round-trip entirely.

Two execution modes share the worker loop:

* **closed** — :meth:`run` submits a whole graph at t=0 and drains it
  (the classic batch mode; with ``arrivals`` the graph is instead
  released over wall time from the arrival timeline);
* **open** — :meth:`start` spawns workers with no work, :meth:`submit`
  feeds tasks incrementally from any thread, and :meth:`close` waits for
  arrivals to stop and the queue to drain (termination = closed ∧
  drained).  :meth:`submit` after :meth:`close` raises — the task could
  never run.

All task lifecycle, worker state and prediction events are published on
``self.bus`` — attach a :class:`~repro.trace.TraceRecorder` to record a
run for deterministic what-if replay in the simulator (worker-side
events carry per-stream sequence stamps; the recorder merge-sorts them
back into canonical order at flush time).
"""

from __future__ import annotations

import threading
import time
from typing import Iterable

from ..analysis import guarded_by
from ..core.energy import PowerModel
from ..core.events import EventBus
from ..core.governor import (DEFAULT_MIN_SAMPLES, GovernorReport,
                             GovernorSpec, ResourceGovernor)
from ..core.manager import WorkerState
from ..core.policies import PollDecision
from ..core.prediction import PredictionConfig
from ..workloads.arrivals import ArrivalProcess
from .sharded import ShardedScheduler
from .task import Task, TaskGraph

__all__ = ["ThreadExecutor", "ExecutorReport"]

#: kept as an alias so downstream code reads one schema everywhere
ExecutorReport = GovernorReport

#: belt-and-suspenders re-check interval for a parked worker — the
#: targeted wake event is the real signal (plus the ≥1 ms ticker's
#: anti-starvation resume path); a timeout firing means both were
#: missed, and the executor counts it (see ``wake_timeouts``)
_IDLE_RECHECK_S = 0.5

#: spin pacing: a worker that keeps missing yields the GIL bare for the
#: first N polls (immediate pickup of fresh work), then naps briefly
#: between polls.  The lock-free poll made a spin iteration so short
#: that N spinners hot-yielding starved the threads with actual work of
#: GIL time (the old globally-locked poll throttled spinners by
#: *blocking* them); the nap restores that pacing with a bounded,
#: explicit cost — worst-case extra pickup latency is one nap.
_SPIN_YIELDS = 10
_SPIN_NAP_S = 50e-6


@guarded_by("_submitted_total", lock="_submit_lock")
class ThreadExecutor:
    def __init__(self, n_workers: int | None = None, policy: str = "busy",
                 spec: GovernorSpec | None = None,
                 monitoring: bool | None = None,
                 prediction_rate_s: float = 1e-3,
                 spin_budget: int = 100,
                 min_samples: int = DEFAULT_MIN_SAMPLES,
                 power: PowerModel | None = None,
                 bus: EventBus | None = None) -> None:
        if spec is None:
            if n_workers is None:
                raise ValueError("need n_workers (or a GovernorSpec)")
            if n_workers < 1:
                raise ValueError("need at least one worker")
            spec = GovernorSpec(
                resources=n_workers, policy=policy,
                prediction=PredictionConfig(rate_s=prediction_rate_s,
                                            min_samples=min_samples),
                spin_budget=spin_budget, monitoring=monitoring, power=power)
        self.spec = spec
        self.n_workers = spec.resources
        self.policy_name = spec.policy
        self._t0 = time.perf_counter()
        self.bus = bus if bus is not None else EventBus()
        self.governor = ResourceGovernor(spec, clock=self._clock,
                                         bus=self.bus)
        if self.governor.sharing:
            raise ValueError(
                "LEND policies need a broker-aware executor (use the "
                "simulator for DLB experiments)")
        self.monitor = self.governor.monitor
        self.predictor = self.governor.predictor
        self.policy = self.governor.policy
        self.energy = self.governor.energy
        self.manager = self.governor.manager
        self.scheduler = ShardedScheduler(self.n_workers, self.monitor,
                                          bus=self.bus, clock=self._clock)
        # Alg. 1 uses spec.prediction.rate_s for its workload math, but a
        # real-time ticker thread cannot honor microsecond rates (the
        # simulator's 50 µs default would busy-loop a core); floor the
        # wall-clock tick interval at 1 ms.
        self.prediction_rate_s = max(spec.prediction.rate_s, 1e-3)
        # Per-worker park/wake events: the manager's targeted waker sets
        # exactly the resumed worker's event (Event construction is
        # fine here — the executor's own lock discipline covers only
        # _submit_lock; Events park, they do not guard state).
        self._wake = {w: threading.Event() for w in range(self.n_workers)}
        self.manager.set_waker(self._wake_worker)
        # Diagnostics: a parked worker that resumed via the 0.5 s
        # re-check timeout instead of its wake event (or shutdown).
        # Single-writer per slot (the owning worker).
        self._wake_timeouts = [0] * self.n_workers
        self._shutdown = False
        # Open-workload mode: while the run is "open", a drained queue
        # does NOT terminate the workers — more submissions may arrive.
        self._closing = False
        self._threads: list[threading.Thread] = []
        self._ticker_thread: threading.Thread | None = None
        self._t_start: float | None = None
        self._submit_lock = threading.Lock()
        self._submitted_total = 0

    def _clock(self) -> float:
        return time.perf_counter() - self._t0

    def _wake_worker(self, worker_id: int) -> None:
        self._wake[worker_id].set()

    @property
    def wake_timeouts(self) -> int:
        """How many times a parked worker resumed via the re-check
        timeout rather than a targeted wake — 0 on a healthy run whose
        idle stretches are shorter than the re-check interval (the
        missed-wakeup regression signal)."""
        return sum(self._wake_timeouts)

    # -- worker loop -----------------------------------------------------------

    def _worker(self, wid: int) -> None:
        scheduler = self.scheduler
        governor = self.governor
        manager = self.manager
        busy_spin = self.policy.never_idles
        wake = self._wake[wid]
        misses = 0
        while True:
            task = scheduler.poll(wid)
            if task is not None:
                misses = 0
                governor.on_task_started(wid)
                t0 = time.perf_counter()
                if task.fn is not None:
                    task.fn()
                elif task.service_time is not None:
                    time.sleep(task.service_time)
                elapsed = time.perf_counter() - t0
                governor.on_task_finished(wid)
                newly = scheduler.complete(task, elapsed, worker_id=wid)
                if newly and not busy_spin:
                    # Never-idling policies have nobody to wake (no
                    # worker ever parks), so skip the per-completion
                    # manager round-trip; successors are already visible
                    # on this worker's shard for everyone to steal.
                    self._on_work_added()
                if self._closing and scheduler.drained():
                    self._finish()
                continue
            # Out of work (from this shard's view): the monitor must not
            # run stale while we spin or park.
            scheduler.flush_worker(wid)
            if self._shutdown:
                return
            misses += 1
            if busy_spin:
                # Never-idling policy: the decision is always SPIN, so
                # skip the per-poll manager lock round-trip.
                time.sleep(0 if misses <= _SPIN_YIELDS else _SPIN_NAP_S)
                continue
            decision = governor.on_poll_empty(wid)
            if decision is PollDecision.SPIN:
                time.sleep(0 if misses <= _SPIN_YIELDS else _SPIN_NAP_S)
                continue
            if decision is PollDecision.IDLE:
                # Park on our own event.  Clearing *before* the state
                # check makes the race benign in both directions: a wake
                # that lands before the clear has already made the SPIN
                # transition visible (the waker runs after the manager
                # lock is released), so the check breaks the loop; one
                # that lands after the clear trips wait() immediately.
                wake.clear()
                while (manager.state_of(wid) is WorkerState.IDLE
                       and not self._shutdown):
                    if wake.wait(timeout=_IDLE_RECHECK_S):
                        wake.clear()
                    else:
                        self._wake_timeouts[wid] += 1
                continue
            raise RuntimeError(
                "LEND decisions need a broker-aware executor (use the "
                "simulator for DLB experiments)")

    def _on_work_added(self) -> None:
        # The manager's targeted waker (set_waker) delivers the actual
        # wakes — one Event.set per resumed worker, not notify_all.
        self.governor.on_tasks_added(self.scheduler.ready_count)

    def _finish(self) -> None:
        self._shutdown = True
        for ev in self._wake.values():
            ev.set()   # unpark everyone so they can observe shutdown

    def _ticker(self) -> None:
        while not self._shutdown:
            time.sleep(self.prediction_rate_s)
            if self._shutdown:
                return
            self.governor.tick()
            if self.policy.uses_predictions:
                self.governor.reevaluate_spinners()
            # Anti-starvation: if ready work exists, apply the resume path.
            if self.scheduler.ready_count > 0:
                self._on_work_added()

    # -- open-workload API ----------------------------------------------------

    def start(self) -> "ThreadExecutor":
        """Spawn workers with no work yet; feed them via :meth:`submit`.

        The run stays open — workers park/spin through empty phases per
        policy — until :meth:`close` is called.
        """
        if self._threads:
            raise RuntimeError("executor already started")
        self._threads = [threading.Thread(target=self._worker, args=(w,),
                                          name=f"worker-{w}", daemon=True)
                         for w in range(self.n_workers)]
        self._ticker_thread = threading.Thread(target=self._ticker,
                                               name="ticker", daemon=True)
        self._t_start = time.perf_counter()
        # Re-epoch the clock: the energy meter has been integrating SPIN
        # power since construction, but the run starts now — otherwise an
        # executor built ahead of its first submission (the natural open-
        # mode shape) reports energy over a window makespan never covers.
        self._t0 = self._t_start
        for t in self._threads:
            t.start()
        self._ticker_thread.start()
        return self

    def submit(self, work: Task | TaskGraph | Iterable[Task]) -> int:
        """Incrementally submit a task, a graph, or an iterable of tasks;
        returns how many became ready immediately.  Thread-safe; callable
        before :meth:`start` (work queues up) or while running — but not
        once :meth:`close` has been called (the run is draining; the
        submission would sit in the queue forever)."""
        if self._closing:
            raise RuntimeError(
                "submit() after close(): the executor is draining and no "
                "worker will ever run this task")
        if isinstance(work, Task):
            tasks: list[Task] = [work]
        elif isinstance(work, TaskGraph):
            tasks = work.tasks
        else:
            tasks = list(work)
        with self._submit_lock:
            self._submitted_total += len(tasks)
        n_ready = self.scheduler.submit_all(tasks)
        if n_ready:
            self._on_work_added()
        return n_ready

    def close(self) -> GovernorReport:
        """No more submissions: wait until drained, stop workers, report.

        Termination = arrivals exhausted (the caller stopped submitting)
        ∧ queue drained — the open-workload contract.
        """
        if not self._threads:
            raise RuntimeError("executor was never started")
        self._closing = True
        if self.scheduler.drained():
            self._finish()
        for t in self._threads:
            t.join()
        assert self._ticker_thread is not None
        self._ticker_thread.join()
        assert self._t_start is not None
        makespan = time.perf_counter() - self._t_start
        # Workers flush their buffers on the way out; this backstop
        # covers buffers a crashed task's thread left behind.
        self.scheduler.flush_all()
        self.governor.finish(self._clock())
        return self.governor.report(makespan=makespan,
                                    tasks_fallback=self._submitted_total)

    # -- public API -----------------------------------------------------------------

    def run(self, graph: TaskGraph,
            arrivals: ArrivalProcess | None = None) -> GovernorReport:
        """Execute ``graph`` to completion and report.

        Without ``arrivals`` this is the closed-world batch mode (whole
        graph submitted at t=0) — unless tasks carry pre-stamped
        ``release_time``\\ s (e.g. a replayed trace), which are honored
        exactly like the simulator honors them.  With ``arrivals``,
        tasks are released over wall time following the process timeline
        — an open-workload run on real threads.
        """
        if not graph.tasks:
            # A graph with no tasks is already drained: report without
            # spawning workers (a worker-side shutdown could otherwise
            # never trigger — it only fires on task completion).
            self.governor.finish(self._clock())
            return self.governor.report(makespan=0.0)
        if arrivals is not None:
            timed = list(zip(graph.tasks, arrivals.assign(graph.tasks)))
        else:
            timed = [(t, t.release_time or 0.0) for t in graph.tasks]
            timed.sort(key=lambda p: p[1])   # pre-stamped order is free
        if timed[-1][1] <= 0.0:
            # Submit before flagging the drain — submit() refuses work
            # once _closing is set, and no worker is running yet.
            self.submit(graph)
            self._closing = True
            self.start()
            return self.close()
        # Open mode: this thread plays the arrival timeline in real time.
        self.start()
        t_begin = time.perf_counter()
        for task, rt in timed:
            delay = rt - (time.perf_counter() - t_begin)
            if delay > 0:
                time.sleep(delay)
            self.submit(task)
        return self.close()
