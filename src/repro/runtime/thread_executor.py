"""Real threaded executor.

Runs task graphs with actual Python threads — the correctness twin of the
simulator (same governor-assembled Scheduler / WorkerManager / Policy /
TaskMonitor objects).  Python's GIL means no true parallel speedup on this
host; the executor exists to validate the concurrency logic (locking,
idle/resume protocol, monitor event ordering) under real preemption, and
to measure the *real* bookkeeping overhead of the monitoring
infrastructure (``benchmarks/bench_overhead.py``).

The whole resource stack is declared by a
:class:`~repro.core.governor.GovernorSpec` and assembled by
:class:`~repro.core.governor.ResourceGovernor`; the executor only owns the
threads, the condition variable and the scheduler.
"""

from __future__ import annotations

import threading
import time

from ..core.energy import PowerModel
from ..core.governor import (DEFAULT_MIN_SAMPLES, GovernorReport,
                             GovernorSpec, ResourceGovernor)
from ..core.manager import WorkerState
from ..core.policies import PollDecision
from ..core.prediction import PredictionConfig
from .scheduler import Scheduler
from .task import TaskGraph

__all__ = ["ThreadExecutor", "ExecutorReport"]

#: kept as an alias so downstream code reads one schema everywhere
ExecutorReport = GovernorReport


class ThreadExecutor:
    def __init__(self, n_workers: int | None = None, policy: str = "busy",
                 spec: GovernorSpec | None = None,
                 monitoring: bool | None = None,
                 prediction_rate_s: float = 1e-3,
                 spin_budget: int = 100,
                 min_samples: int = DEFAULT_MIN_SAMPLES,
                 power: PowerModel | None = None) -> None:
        if spec is None:
            if n_workers is None:
                raise ValueError("need n_workers (or a GovernorSpec)")
            if n_workers < 1:
                raise ValueError("need at least one worker")
            spec = GovernorSpec(
                resources=n_workers, policy=policy,
                prediction=PredictionConfig(rate_s=prediction_rate_s,
                                            min_samples=min_samples),
                spin_budget=spin_budget, monitoring=monitoring, power=power)
        self.spec = spec
        self.n_workers = spec.resources
        self.policy_name = spec.policy
        self._t0 = time.perf_counter()
        self.governor = ResourceGovernor(spec, clock=self._clock)
        if self.governor.sharing:
            raise ValueError(
                "LEND policies need a broker-aware executor (use the "
                "simulator for DLB experiments)")
        self.monitor = self.governor.monitor
        self.predictor = self.governor.predictor
        self.policy = self.governor.policy
        self.energy = self.governor.energy
        self.manager = self.governor.manager
        self.scheduler = Scheduler(self.monitor)
        # Alg. 1 uses spec.prediction.rate_s for its workload math, but a
        # real-time ticker thread cannot honor microsecond rates (the
        # simulator's 50 µs default would busy-loop a core); floor the
        # wall-clock tick interval at 1 ms.
        self.prediction_rate_s = max(spec.prediction.rate_s, 1e-3)
        self._cv = threading.Condition()
        self._shutdown = False

    def _clock(self) -> float:
        return time.perf_counter() - self._t0

    # -- worker loop -----------------------------------------------------------

    def _worker(self, wid: int) -> None:
        while True:
            task = self.scheduler.poll()
            if task is not None:
                self.governor.on_task_started(wid)
                t0 = time.perf_counter()
                if task.fn is not None:
                    task.fn()
                elif task.service_time is not None:
                    time.sleep(task.service_time)
                elapsed = time.perf_counter() - t0
                self.governor.on_task_finished(wid)
                newly = self.scheduler.complete(task, elapsed)
                if newly:
                    self._on_work_added()
                if self.scheduler.drained():
                    self._finish()
                continue
            if self._shutdown:
                return
            decision = self.governor.on_poll_empty(wid)
            if decision is PollDecision.SPIN:
                time.sleep(0)  # yield the GIL
                continue
            if decision is PollDecision.IDLE:
                with self._cv:
                    while (self.manager.state(wid) is WorkerState.IDLE
                           and not self._shutdown):
                        self._cv.wait(timeout=0.05)
                continue
            raise RuntimeError(
                "LEND decisions need a broker-aware executor (use the "
                "simulator for DLB experiments)")

    def _on_work_added(self) -> None:
        woken = self.governor.on_tasks_added(self.scheduler.ready_count)
        if woken:
            with self._cv:
                self._cv.notify_all()

    def _finish(self) -> None:
        self._shutdown = True
        with self._cv:
            self._cv.notify_all()  # unpark idle workers so they can exit

    def _ticker(self) -> None:
        while not self._shutdown:
            time.sleep(self.prediction_rate_s)
            if self._shutdown:
                return
            self.governor.tick()
            if self.policy.uses_predictions:
                self.governor.reevaluate_spinners()
            # Anti-starvation: if ready work exists, apply the resume path.
            if self.scheduler.ready_count > 0:
                self._on_work_added()

    # -- public API -----------------------------------------------------------------

    def run(self, graph: TaskGraph) -> GovernorReport:
        self.scheduler.submit_all(graph.tasks)
        threads = [threading.Thread(target=self._worker, args=(w,),
                                    name=f"worker-{w}", daemon=True)
                   for w in range(self.n_workers)]
        ticker = threading.Thread(target=self._ticker, name="ticker",
                                  daemon=True)
        start = time.perf_counter()
        for t in threads:
            t.start()
        ticker.start()
        for t in threads:
            t.join()
        ticker.join()
        makespan = time.perf_counter() - start
        self.governor.finish(self._clock())
        return self.governor.report(makespan=makespan,
                                    tasks_fallback=len(graph.tasks))
