"""Real threaded executor.

Runs task graphs with actual Python threads — the correctness twin of the
simulator (same Scheduler / WorkerManager / Policy / TaskMonitor objects).
Python's GIL means no true parallel speedup on this host; the executor
exists to validate the concurrency logic (locking, idle/resume protocol,
monitor event ordering) under real preemption, and to measure the *real*
bookkeeping overhead of the monitoring infrastructure
(``benchmarks/bench_overhead.py``).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass

from ..core.energy import CoreState, EnergyMeter, PowerModel
from ..core.manager import WorkerManager, WorkerState
from ..core.monitoring import AccuracyReport, TaskMonitor
from ..core.policies import Policy, PollDecision, make_policy
from ..core.prediction import (DEFAULT_PREDICTION_RATE_S, CPUPredictor,
                               PredictionConfig)
from .scheduler import Scheduler
from .task import TaskGraph

__all__ = ["ThreadExecutor", "ExecutorReport"]


@dataclass(frozen=True)
class ExecutorReport:
    policy: str
    makespan: float
    energy: float
    edp: float
    tasks_completed: int
    resumes: int
    idles: int
    predictions: int
    accuracy: AccuracyReport | None


class ThreadExecutor:
    def __init__(self, n_workers: int, policy: str = "busy",
                 monitoring: bool | None = None,
                 prediction_rate_s: float = 1e-3,
                 spin_budget: int = 100,
                 min_samples: int = 4,
                 power: PowerModel | None = None) -> None:
        if n_workers < 1:
            raise ValueError("need at least one worker")
        self.n_workers = n_workers
        self.policy_name = policy
        needs_monitor = policy == "prediction" or bool(monitoring)
        self.monitor = TaskMonitor(min_samples=min_samples) \
            if needs_monitor else None
        self.scheduler = Scheduler(self.monitor)
        self.predictor: CPUPredictor | None = None
        if policy == "prediction":
            assert self.monitor is not None
            self.predictor = CPUPredictor(
                self.monitor, n_cpus=n_workers,
                config=PredictionConfig(rate_s=prediction_rate_s,
                                        min_samples=min_samples))
        self.policy: Policy = make_policy(policy, self.predictor,
                                          spin_budget)
        self.prediction_rate_s = prediction_rate_s
        self._t0 = time.perf_counter()
        self.energy = EnergyMeter(n_workers, power, t0=0.0)
        self.manager = WorkerManager(
            n_workers, self.policy, clock=self._clock, energy=self.energy)
        self._cv = threading.Condition()
        self._shutdown = False

    def _clock(self) -> float:
        return time.perf_counter() - self._t0

    # -- worker loop -----------------------------------------------------------

    def _worker(self, wid: int) -> None:
        while True:
            task = self.scheduler.poll()
            if task is not None:
                self.manager.task_started(wid)
                t0 = time.perf_counter()
                if task.fn is not None:
                    task.fn()
                elif task.service_time is not None:
                    time.sleep(task.service_time)
                elapsed = time.perf_counter() - t0
                self.manager.task_finished(wid)
                newly = self.scheduler.complete(task, elapsed)
                if newly:
                    self._on_work_added()
                if self.scheduler.drained():
                    self._finish()
                continue
            if self._shutdown:
                return
            decision = self.manager.poll_empty(wid)
            if decision is PollDecision.SPIN:
                time.sleep(0)  # yield the GIL
                continue
            if decision is PollDecision.IDLE:
                with self._cv:
                    while (self.manager.state(wid) is WorkerState.IDLE
                           and not self._shutdown):
                        self._cv.wait(timeout=0.05)
                continue
            raise RuntimeError(
                "LEND decisions need a broker-aware executor (use the "
                "simulator for DLB experiments)")

    def _on_work_added(self) -> None:
        woken = self.manager.notify_added(self.scheduler.ready_count)
        if woken:
            with self._cv:
                self._cv.notify_all()

    def _finish(self) -> None:
        self._shutdown = True
        with self._cv:
            self._cv.notify_all()  # unpark idle workers so they can exit

    def _ticker(self) -> None:
        while not self._shutdown:
            time.sleep(self.prediction_rate_s)
            if self._shutdown:
                return
            self.policy.on_prediction_tick()
            if self.policy.uses_predictions:
                self.manager.reevaluate_spinners()
            # Anti-starvation: if ready work exists, apply the resume path.
            if self.scheduler.ready_count > 0:
                self._on_work_added()

    # -- public API -----------------------------------------------------------------

    def run(self, graph: TaskGraph) -> ExecutorReport:
        self.scheduler.submit_all(graph.tasks)
        threads = [threading.Thread(target=self._worker, args=(w,),
                                    name=f"worker-{w}", daemon=True)
                   for w in range(self.n_workers)]
        ticker = threading.Thread(target=self._ticker, name="ticker",
                                  daemon=True)
        start = time.perf_counter()
        for t in threads:
            t.start()
        ticker.start()
        for t in threads:
            t.join()
        ticker.join()
        makespan = time.perf_counter() - start
        self.energy.finish(self._clock())
        acc = self.monitor.accuracy_report() if self.monitor else None
        return ExecutorReport(
            policy=self.policy_name,
            makespan=makespan,
            energy=self.energy.energy(),
            edp=self.energy.energy() * makespan,
            tasks_completed=(self.monitor.completed_instances()
                             if self.monitor else len(graph.tasks)),
            resumes=self.manager.resumes,
            idles=self.manager.idles,
            predictions=(self.predictor.predictions_made
                         if self.predictor else 0),
            accuracy=acc,
        )
