"""Multi-application co-scheduling frontend (multiprogramming).

:func:`run_multi_app` is the one-call entry point for the scenario the
paper's §2/§3.3 sharing story implies but the repo never had: N
applications — each with its own policy, monitor/predictor and arrival
process — co-scheduled on ONE machine through the
:class:`~repro.core.sharing.ResourceBroker`, with the
:class:`~repro.core.arbiter.ClusterArbiter` redistributing cores from
per-app predictions.  The result is a
:class:`~repro.core.arbiter.MultiAppReport`: per-app
:class:`~repro.core.governor.GovernorReport`\\ s plus cluster-level
fairness metrics (per-app slowdown vs. a solo run on the same CPU
partition, Jain fairness, aggregate EDP, total DLB calls).

Solo baselines: task graphs are single-use (the scheduler mutates task
state), so callers wanting slowdown metrics pass ``solo_graphs`` — a
second, freshly-built copy of each app's graph.  Each baseline runs
alone on the app's own CPU partition under the policy's registered
``solo_equivalent`` (dlb-lewi → idle, dlb-hybrid → hybrid,
dlb-prediction → prediction): a sharing policy with no co-tenant would
deadlock its lent CPUs, and the paper's "Single" configuration idles
unused CPUs too.
"""

from __future__ import annotations

from dataclasses import replace
from typing import Iterable, Mapping

from ..core.arbiter import ClusterArbiter, MultiAppReport
from ..core.governor import GovernorReport, policy_entry
from ..core.monitoring import TaskMonitor
from ..core.prediction import CPUPredictor, PredictionConfig
from ..core.sharing import ResourceBroker
from .cluster import ClusterModel
from .machine import MachineModel
from .sim import SimCluster, SimJobSpec
from .task import TaskGraph

__all__ = ["run_multi_app", "run_multi_node", "solo_job_spec",
           "predicted_demand"]


def solo_job_spec(spec: SimJobSpec, graph: TaskGraph) -> SimJobSpec:
    """``spec`` rewritten for a solo (fairness-baseline) run: fresh
    ``graph``, sharing policy swapped for its registry-declared solo
    equivalent, private bus."""
    if spec.governor is not None:
        entry = policy_entry(spec.governor.policy)
        gov = (replace(spec.governor, policy=entry.solo_equivalent)
               if entry.solo_equivalent else spec.governor)
        return replace(spec, graph=graph, governor=gov, bus=None)
    entry = policy_entry(spec.policy)
    solo_policy = entry.solo_equivalent or spec.policy
    return replace(spec, graph=graph, policy=solo_policy, bus=None)


def run_multi_app(machine: MachineModel, specs: Iterable[SimJobSpec], *,
                  broker: ResourceBroker | None = None,
                  solo_graphs: Mapping[str, TaskGraph] | None = None,
                  threadsafe: bool = False) -> MultiAppReport:
    """Co-schedule ``specs`` on ``machine`` through one broker/arbiter.

    Every spec must pin its CPU partition (``spec.cpus``) — silent
    overlapping defaults are exactly the class of bug multiprogramming
    runs cannot afford.  ``solo_graphs`` (app name → fresh graph copy)
    enables the slowdown/fairness metrics; apps without an entry simply
    have no baseline.
    """
    specs = list(specs)
    if not specs:
        raise ValueError("run_multi_app needs at least one SimJobSpec")
    seen: set[int] = set()
    for spec in specs:
        if spec.cpus is None:
            raise ValueError(
                f"app {spec.name!r} has no cpus: multi-app runs require "
                "explicit, disjoint CPU partitions")
        overlap = seen & set(spec.cpus)
        if overlap:
            raise ValueError(
                f"app {spec.name!r} overlaps already-assigned cpus "
                f"{sorted(overlap)[:5]}")
        seen |= set(spec.cpus)
    if broker is None:
        broker = ResourceBroker()
    cluster = SimCluster(machine, broker=broker, threadsafe=threadsafe)
    for spec in specs:
        cluster.add_job(spec)
    reports = cluster.run()

    solo: dict[str, GovernorReport] = {}
    if solo_graphs:
        for spec in specs:
            graph = solo_graphs.get(spec.name)
            if graph is None:
                continue
            solo_cluster = SimCluster(machine, threadsafe=threadsafe)
            solo_cluster.add_job(solo_job_spec(spec, graph))
            solo[spec.name] = solo_cluster.run()[spec.name]
    return MultiAppReport.build(reports, broker.total_calls, solo or None)


def predicted_demand(spec: SimJobSpec) -> float:
    """Pre-run CPU-demand estimate for one app, from its *own*
    prediction machinery — the number the arbiter's ``"predicted"``
    placement packs nodes by.

    The whole graph is fed through a private
    :class:`~repro.core.monitoring.TaskMonitor` (every task's service
    time becomes a timing sample), the tasks are re-marked ready as live
    work, and one Algorithm-1 pass with the prediction window set to the
    graph's critical path yields Δ ≈ total work / critical path — the
    app's mean parallelism.  Costless relative to a run: no events, no
    simulator, O(tasks + edges).
    """
    tasks = spec.graph.tasks
    if not tasks:
        return 0.0
    monitor = TaskMonitor(min_samples=1)
    for t in tasks:
        st = t.service_time if t.service_time is not None else t.cost
        monitor.on_task_ready(t.task_id, t.type_name, t.cost)
        monitor.on_task_execute(t.task_id, t.type_name, t.cost)
        monitor.on_task_completed(t.task_id, t.type_name, t.cost, st)
    # critical path over the dependency DAG (iterative: graphs can be
    # deep chains)
    memo: dict[int, float] = {}
    for root in tasks:
        stack = [root]
        while stack:
            t = stack[-1]
            if t.task_id in memo:
                stack.pop()
                continue
            todo = [d for d in t.deps if d.task_id not in memo]
            if todo:
                stack.extend(todo)
                continue
            st = t.service_time if t.service_time is not None else t.cost
            memo[t.task_id] = st + max(
                (memo[d.task_id] for d in t.deps), default=0.0)
            stack.pop()
    critical = max(memo.values())
    if critical <= 0.0:
        return 1.0
    for t in tasks:
        monitor.on_task_ready(t.task_id, t.type_name, t.cost)
    predictor = CPUPredictor(
        monitor, n_cpus=len(tasks),
        config=PredictionConfig(rate_s=critical, min_samples=1))
    return float(predictor.tick())


def run_multi_node(cluster: ClusterModel, specs: Iterable[SimJobSpec], *,
                   placement: str | Mapping[str, int] = "predicted",
                   broker: ResourceBroker | None = None,
                   solo_graphs: Mapping[str, TaskGraph] | None = None,
                   threadsafe: bool = False) -> MultiAppReport:
    """Co-schedule ``specs`` across a multi-node :class:`ClusterModel`.

    ``placement`` is either a policy name handed to
    :meth:`~repro.core.arbiter.ClusterArbiter.place` (``"predicted"``
    packs by each app's :func:`predicted_demand`, ``"round-robin"``
    ignores demand) or an explicit app → node mapping.  Each node's
    cores are split evenly between the apps placed on it; a spec that
    pins ``cpus`` keeps them (its node derives from its first cpu).
    The report's ``placement`` field records the homes chosen.
    """
    specs = list(specs)
    if not specs:
        raise ValueError("run_multi_node needs at least one SimJobSpec")
    names = [s.name for s in specs]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate app names: {names}")
    pinned = {s.name: list(s.cpus) for s in specs if s.cpus is not None}
    auto = [s for s in specs if s.cpus is None]
    if isinstance(placement, str):
        demands = {s.name: predicted_demand(s) for s in auto}
        homes = ClusterArbiter.place(
            demands, [m.n_cores for m in cluster.nodes], policy=placement)
    else:
        homes = {s.name: placement[s.name] for s in auto}
    for s in specs:
        if s.name in pinned:
            homes[s.name] = (s.node if s.node is not None
                             else cluster.node_of(pinned[s.name][0]))
    # Per-node even split of the cores not already pinned away.
    by_node: dict[int, list[SimJobSpec]] = {}
    for s in auto:
        by_node.setdefault(homes[s.name], []).append(s)
    taken = {c for cpus in pinned.values() for c in cpus}
    assigned: dict[str, list[int]] = dict(pinned)
    for node, node_specs in by_node.items():
        cores = [c for c in cluster.cores_of(node) if c not in taken]
        share = len(cores) // len(node_specs)
        if share == 0:
            raise ValueError(
                f"node {node} has {len(cores)} free core(s) for "
                f"{len(node_specs)} app(s)")
        for i, s in enumerate(node_specs):
            lo = i * share
            hi = lo + share if i < len(node_specs) - 1 else len(cores)
            assigned[s.name] = cores[lo:hi]
    run_specs = [replace(s, cpus=assigned[s.name], node=homes[s.name])
                 for s in specs]
    if broker is None:
        broker = ResourceBroker()
    sim = SimCluster(cluster, broker=broker, threadsafe=threadsafe)
    for s in run_specs:
        sim.add_job(s)
    reports = sim.run()

    solo: dict[str, GovernorReport] = {}
    if solo_graphs:
        for s in run_specs:
            graph = solo_graphs.get(s.name)
            if graph is None:
                continue
            solo_sim = SimCluster(cluster, threadsafe=threadsafe)
            solo_sim.add_job(solo_job_spec(s, graph))
            solo[s.name] = solo_sim.run()[s.name]
    return MultiAppReport.build(reports, broker.total_calls, solo or None,
                                placement=homes)
