"""Multi-application co-scheduling frontend (multiprogramming).

:func:`run_multi_app` is the one-call entry point for the scenario the
paper's §2/§3.3 sharing story implies but the repo never had: N
applications — each with its own policy, monitor/predictor and arrival
process — co-scheduled on ONE machine through the
:class:`~repro.core.sharing.ResourceBroker`, with the
:class:`~repro.core.arbiter.ClusterArbiter` redistributing cores from
per-app predictions.  The result is a
:class:`~repro.core.arbiter.MultiAppReport`: per-app
:class:`~repro.core.governor.GovernorReport`\\ s plus cluster-level
fairness metrics (per-app slowdown vs. a solo run on the same CPU
partition, Jain fairness, aggregate EDP, total DLB calls).

Solo baselines: task graphs are single-use (the scheduler mutates task
state), so callers wanting slowdown metrics pass ``solo_graphs`` — a
second, freshly-built copy of each app's graph.  Each baseline runs
alone on the app's own CPU partition under the policy's registered
``solo_equivalent`` (dlb-lewi → idle, dlb-hybrid → hybrid,
dlb-prediction → prediction): a sharing policy with no co-tenant would
deadlock its lent CPUs, and the paper's "Single" configuration idles
unused CPUs too.
"""

from __future__ import annotations

from dataclasses import replace
from typing import Iterable, Mapping

from ..core.arbiter import MultiAppReport
from ..core.governor import GovernorReport, policy_entry
from ..core.sharing import ResourceBroker
from .machine import MachineModel
from .sim import SimCluster, SimJobSpec
from .task import TaskGraph

__all__ = ["run_multi_app", "solo_job_spec"]


def solo_job_spec(spec: SimJobSpec, graph: TaskGraph) -> SimJobSpec:
    """``spec`` rewritten for a solo (fairness-baseline) run: fresh
    ``graph``, sharing policy swapped for its registry-declared solo
    equivalent, private bus."""
    if spec.governor is not None:
        entry = policy_entry(spec.governor.policy)
        gov = (replace(spec.governor, policy=entry.solo_equivalent)
               if entry.solo_equivalent else spec.governor)
        return replace(spec, graph=graph, governor=gov, bus=None)
    entry = policy_entry(spec.policy)
    solo_policy = entry.solo_equivalent or spec.policy
    return replace(spec, graph=graph, policy=solo_policy, bus=None)


def run_multi_app(machine: MachineModel, specs: Iterable[SimJobSpec], *,
                  broker: ResourceBroker | None = None,
                  solo_graphs: Mapping[str, TaskGraph] | None = None,
                  threadsafe: bool = False) -> MultiAppReport:
    """Co-schedule ``specs`` on ``machine`` through one broker/arbiter.

    Every spec must pin its CPU partition (``spec.cpus``) — silent
    overlapping defaults are exactly the class of bug multiprogramming
    runs cannot afford.  ``solo_graphs`` (app name → fresh graph copy)
    enables the slowdown/fairness metrics; apps without an entry simply
    have no baseline.
    """
    specs = list(specs)
    if not specs:
        raise ValueError("run_multi_app needs at least one SimJobSpec")
    seen: set[int] = set()
    for spec in specs:
        if spec.cpus is None:
            raise ValueError(
                f"app {spec.name!r} has no cpus: multi-app runs require "
                "explicit, disjoint CPU partitions")
        overlap = seen & set(spec.cpus)
        if overlap:
            raise ValueError(
                f"app {spec.name!r} overlaps already-assigned cpus "
                f"{sorted(overlap)[:5]}")
        seen |= set(spec.cpus)
    if broker is None:
        broker = ResourceBroker()
    cluster = SimCluster(machine, broker=broker, threadsafe=threadsafe)
    for spec in specs:
        cluster.add_job(spec)
    reports = cluster.run()

    solo: dict[str, GovernorReport] = {}
    if solo_graphs:
        for spec in specs:
            graph = solo_graphs.get(spec.name)
            if graph is None:
                continue
            solo_cluster = SimCluster(machine, threadsafe=threadsafe)
            solo_cluster.add_job(solo_job_spec(spec, graph))
            solo[spec.name] = solo_cluster.run()[spec.name]
    return MultiAppReport.build(reports, broker.total_calls, solo or None)
