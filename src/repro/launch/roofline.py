"""Roofline analysis from the compiled dry-run artifact.

``jax.stages.Compiled.cost_analysis()`` on this XLA build reports
*per-device* flops and counts a ``while`` (scan) body **once** (verified
in ``tests/test_roofline.py`` against an unrolled toy).  This module
therefore walks the compiled HLO text itself:

* computations are parsed into op lists with a result-shape symbol table;
* ``while`` bodies are scaled by their trip count (recovered from the
  loop-condition comparison constant — scans lower to counted loops);
* FLOPs come from ``dot`` ops (2 · |result| · |contraction|), recursing
  into output fusions;
* HBM bytes are modeled per top-level op as operands + result (fusions
  internalize their interior; slice/gather/update ops count only the
  moved slice, not the full buffer);
* collective bytes-on-wire per device use ring formulas over the
  replica-group size g: all-gather / all-to-all / reduce-scatter move
  size·(g−1)/g, all-reduce 2·size·(g−1)/g, collective-permute size.

Terms (per device, seconds):
    compute    = flops / PEAK_FLOPS
    memory     = hbm_bytes / HBM_BW
    collective = wire_bytes / (n_links · ICI_BW)
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

from .mesh import HW

__all__ = ["HloAnalysis", "analyze_hlo", "roofline_terms", "Terms"]

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3b11fnuz": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$")
_REGION_RE = re.compile(r'op_name="[^"]*pallas:([\w\-]+)')
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[\d, ]+\}(?:,\{[\d, ]+\})*)\}")
_CALL_RE = re.compile(r"(?:calls|body|condition|to_apply)=%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(type_sig: str) -> int:
    return sum(_shape_bytes(dt, dims)
               for dt, dims in _SHAPE_RE.findall(type_sig))


@dataclass
class _Op:
    name: str
    opcode: str
    type_sig: str
    rest: str           # everything after the opening paren
    result_bytes: int
    region: str | None = None   # "pallas:<name>" kernel region tag
    is_root: bool = False


@dataclass
class _Computation:
    name: str
    ops: list = field(default_factory=list)


@dataclass
class HloAnalysis:
    """Per-device totals (trip-count scaled)."""

    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0       # bytes on wire per device
    collective_by_kind: dict = field(default_factory=dict)
    collective_count: int = 0
    while_trips: dict = field(default_factory=dict)
    #: bytes removed by fusing "pallas:" regions (interior stays VMEM)
    kernel_bytes_saved: float = 0.0
    kernel_boundary_bytes: float = 0.0
    notes: list = field(default_factory=list)

    def to_json(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_by_kind": self.collective_by_kind,
            "collective_count": self.collective_count,
            "while_trips": self.while_trips,
            "kernel_bytes_saved": self.kernel_bytes_saved,
            "kernel_boundary_bytes": self.kernel_boundary_bytes,
            "notes": self.notes,
        }


def _parse_computations(text: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m and line.rstrip().endswith("{"):
            cur = _Computation(m.group(1))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        om = _OP_RE.match(line)
        if om:
            name, type_sig, opcode, rest = om.groups()
            rm = _REGION_RE.search(line)
            cur.ops.append(_Op(name, opcode, type_sig, rest,
                               _result_bytes(type_sig),
                               region=rm.group(1) if rm else None,
                               is_root=line.lstrip().startswith("ROOT")))
    return comps


def _group_size(rest: str, n_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        first = m.group(1).split("},")[0].strip("{}")
        return len([x for x in first.split(",") if x.strip() != ""])
    return n_devices


def _operand_names(rest: str) -> list[str]:
    # operands are inside the first balanced paren group of `rest`
    depth = 1
    out = []
    buf = []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    return re.findall(r"%([\w.\-]+)", "".join(buf))


def _trip_count(cond: _Computation) -> int:
    consts = []
    for op in cond.ops:
        consts += [int(c) for c in _CONST_RE.findall(
            op.opcode + "(" + op.rest)]
        if op.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", "constant(" + op.rest)
            if m:
                consts.append(int(m.group(1)))
    consts = [c for c in consts if c > 0]
    return max(consts) if consts else 1


class _Walker:
    def _symbols(self, comp: _Computation) -> dict[str, int]:
        """name → bytes, with width-change converts aliased to their
        source: the CPU backend legalizes bf16 compute through f32
        converts that a bf16-native TPU never materializes, so a
        convert's consumers are charged the source width and the convert
        itself carries no traffic.  All-gathers of converted values are
        likewise charged at the source width × gather factor."""
        syms: dict[str, int] = {}
        raw: dict[str, int] = {}
        for op in comp.ops:
            syms[op.name] = op.result_bytes
            raw[op.name] = op.result_bytes
            srcs = _operand_names(op.rest)
            if op.opcode == "convert" and len(srcs) == 1 \
                    and srcs[0] in syms:
                syms[op.name] = min(op.result_bytes, syms[srcs[0]])
            elif op.opcode.startswith("all-gather") and srcs \
                    and srcs[0] in syms and raw.get(srcs[0]):
                ratio = op.result_bytes / raw[srcs[0]]
                syms[op.name] = min(op.result_bytes,
                                    int(syms[srcs[0]] * ratio))
        return syms

    def __init__(self, comps: dict[str, _Computation], n_devices: int,
                 kernel_substitute: bool = False):
        self.comps = comps
        self.n_devices = n_devices
        self.kernel_substitute = kernel_substitute
        self.analysis = HloAnalysis()
        self._memo_flops: dict[str, float] = {}

    # -- dot flops (recursing into fusions) ------------------------------

    def _dot_flops(self, comp: _Computation, syms: dict) -> float:
        if comp.name in self._memo_flops:
            return self._memo_flops[comp.name]
        total = 0.0
        for op in comp.ops:
            if op.opcode == "dot":
                res_elems = 1
                for dt, dims in _SHAPE_RE.findall(op.type_sig):
                    if dims:
                        for d in dims.split(","):
                            res_elems *= int(d)
                    break
                contract = 1
                cm = _CONTRACT_RE.search(op.rest)
                operands = _operand_names(op.rest)
                if cm and operands:
                    lhs_shape = self._op_shape(comp, operands[0])
                    if lhs_shape is not None and cm.group(1):
                        for idx in cm.group(1).split(","):
                            i = int(idx)
                            if i < len(lhs_shape):
                                contract *= lhs_shape[i]
                total += 2.0 * res_elems * contract
            elif op.opcode == "fusion":
                cm = _CALL_RE.search(op.rest)
                if cm and cm.group(1) in self.comps:
                    sub = self.comps[cm.group(1)]
                    total += self._dot_flops(sub, self._symbols(sub))
        self._memo_flops[comp.name] = total
        return total

    def _fusion_bytes(self, op: _Op, syms: dict[str, int]) -> float:
        ins, out = self._fusion_io(op, syms)
        return sum(ins.values()) + out

    def _fusion_io(self, op: _Op, syms: dict[str, int]
                   ) -> tuple[dict[str, float], float]:
        """Per-operand HBM reads + output write of a fusion, modeled
        from its interior:

        * a parameter consumed ONLY by dynamic-slice ops → the slices'
          bytes (loop-buffer reads are slice-sized, not buffer-sized);
        * a parameter that is the in-place destination (operand 0) of a
          dynamic-update-slice → 0 read (aliased in place);
        * any other parameter → read once, full size;
        * output: if the fused root is a dynamic-update-slice, only the
          update is written; else the full result.
        """
        m = _CALL_RE.search(op.rest)
        sub = self.comps.get(m.group(1)) if m else None
        operands = _operand_names(op.rest)
        if sub is None:
            return ({n: syms.get(n, 0) for n in operands},
                    op.result_bytes)
        sub_syms = self._symbols(sub)
        # alias map: convert/bitcast/copy/reshape are transparent — the
        # classification below must see *through* legalization converts
        alias: dict[str, str] = {}

        def resolve(n: str) -> str:
            seen = set()
            while n in alias and n not in seen:
                seen.add(n)
                n = alias[n]
            return n

        for sop in sub.ops:
            if sop.opcode in ("convert", "bitcast", "copy", "reshape"):
                srcs = _operand_names(sop.rest)
                if len(srcs) == 1:
                    alias[sop.name] = srcs[0]
        # parameter name -> argument index
        param_idx: dict[str, int] = {}
        for sop in sub.ops:
            if sop.opcode == "parameter":
                pm = re.match(r"\s*(\d+)", sop.rest)
                if pm:
                    param_idx[sop.name] = int(pm.group(1))
        # effective consumers of each root value
        consumers: dict[str, list[_Op]] = {}
        for sop in sub.ops:
            if sop.opcode in ("convert", "bitcast", "copy", "reshape"):
                continue                     # transparent
            for n in _operand_names(sop.rest):
                consumers.setdefault(resolve(n), []).append(sop)
        ins: dict[str, float] = {}
        for pname, idx in param_idx.items():
            oname = operands[idx] if idx < len(operands) else None
            ext = syms.get(oname, 0) if oname else 0
            cons = consumers.get(pname, [])
            if cons and all(c.opcode == "dynamic-slice" for c in cons):
                val = sum(c.result_bytes for c in cons)
            elif cons and any(
                    c.opcode == "dynamic-update-slice"
                    and resolve(_operand_names(c.rest)[0]) == pname
                    for c in cons if _operand_names(c.rest)):
                val = 0.0                   # in-place destination
            else:
                val = float(ext)
            if oname:
                ins[oname] = ins.get(oname, 0.0) + val
        root = next((sop for sop in sub.ops if sop.is_root),
                    sub.ops[-1] if sub.ops else None)
        root_name = resolve(root.name) if root is not None else None
        root_op = next((sop for sop in sub.ops if sop.name == root_name),
                       root)
        if root_op is not None and root_op.opcode == "dynamic-update-slice":
            upd = _operand_names(root_op.rest)
            out = float(sub_syms.get(resolve(upd[1]), 0)) if len(upd) > 1 \
                else float(op.result_bytes)
        else:
            out = float(op.result_bytes)
        return ins, out

    def _op_shape(self, comp: _Computation, name: str) -> list[int] | None:
        for op in comp.ops:
            if op.name == name:
                m = _SHAPE_RE.search(op.type_sig)
                if m:
                    return [int(d) for d in m.group(2).split(",")] \
                        if m.group(2) else []
        return None

    # -- full walk ----------------------------------------------------------

    def walk(self, comp_name: str, scale: float = 1.0) -> None:
        comp = self.comps[comp_name]
        syms = self._symbols(comp)
        a = self.analysis

        # "pallas:" kernel regions: the interior is VMEM-resident in the
        # fused kernel — HBM traffic is only what crosses the boundary.
        # Region membership resolves through transparent ops (converts,
        # bitcasts) so legalization wrappers don't leak values out.
        region_of: dict[str, str | None] = {}
        consumed_outside: set[str] = set()
        if self.kernel_substitute:
            alias: dict[str, str] = {}
            for op in comp.ops:
                if op.opcode in ("convert", "bitcast", "copy", "reshape",
                                 "transpose"):
                    srcs = _operand_names(op.rest)
                    if len(srcs) == 1:
                        alias[op.name] = srcs[0]

            def rroot(n: str) -> str:
                seen = set()
                while n in alias and n not in seen:
                    seen.add(n)
                    n = alias[n]
                return n

            direct = {op.name: op.region for op in comp.ops}
            for op in comp.ops:
                region_of[op.name] = direct.get(op.name) \
                    or direct.get(rroot(op.name))
            for op in comp.ops:
                my_region = region_of.get(op.name)
                for n in _operand_names(op.rest):
                    src_region = region_of.get(n)
                    if src_region and my_region != src_region:
                        consumed_outside.add(n)
                        consumed_outside.add(rroot(n))
                if op.is_root:
                    consumed_outside.add(op.name)

        def _in_region(op: _Op) -> str | None:
            if not self.kernel_substitute:
                return None
            return op.region

        for op in comp.ops:
            oc = op.opcode
            if oc in ("parameter", "constant", "get-tuple-element",
                      "tuple", "bitcast", "iota", "after-all",
                      "partition-id", "replica-id", "convert"):
                # converts: width-change legalization artifacts on this
                # backend; aliased in the symbol table instead
                continue
            if oc == "while":
                refs = dict(re.findall(r"(body|condition)=%([\w.\-]+)",
                                       op.rest))
                body, cond = refs.get("body"), refs.get("condition")
                trips = _trip_count(self.comps[cond]) if cond else 1
                a.while_trips[body or "?"] = trips
                if body in self.comps:
                    self.walk(body, scale * trips)
                continue
            if oc == "conditional":
                branches = re.findall(r"%([\w.\-]+)", op.rest)
                subs = [b for b in branches if b in self.comps]
                for b in subs[:1]:      # take first branch (true-branch)
                    self.walk(b, scale)
                continue
            if oc in ("call", "async-start"):
                cm = _CALL_RE.search(op.rest)
                if cm and cm.group(1) in self.comps:
                    self.walk(cm.group(1), scale)
                continue
            # ---- collectives -------------------------------------------
            if any(oc.startswith(c) for c in _COLLECTIVES):
                kind = next(c for c in _COLLECTIVES if oc.startswith(c))
                g = _group_size(op.rest, self.n_devices)
                # size at the *aliased* width (a TPU would move bf16
                # where this backend legalized to f32)
                size = op.result_bytes
                srcs = _operand_names(op.rest)
                if kind == "all-gather" and op.name in syms:
                    size = syms[op.name]
                elif srcs:
                    al = sum(syms.get(n, 0) for n in srcs if n in syms)
                    if al:
                        size = min(size, al)
                if kind == "all-gather":
                    wire = size * (g - 1) / max(g, 1)
                elif kind == "all-reduce":
                    wire = 2.0 * size * (g - 1) / max(g, 1)
                elif kind == "reduce-scatter":
                    wire = size * (g - 1)   # result is the scattered shard
                elif kind == "all-to-all":
                    wire = size * (g - 1) / max(g, 1)
                else:                       # collective-permute
                    wire = size
                a.collective_bytes += wire * scale
                a.collective_by_kind[kind] = \
                    a.collective_by_kind.get(kind, 0.0) + wire * scale
                a.collective_count += int(scale) if scale >= 1 else 1
                a.hbm_bytes += 2.0 * size * scale
                continue
            # ---- flops ---------------------------------------------------
            if oc == "dot":
                res_elems = 1
                m = _SHAPE_RE.search(op.type_sig)
                if m and m.group(2):
                    for d in m.group(2).split(","):
                        res_elems *= int(d)
                contract = 1
                cm = _CONTRACT_RE.search(op.rest)
                operands = _operand_names(op.rest)
                if cm and operands:
                    lhs_shape = self._op_shape(comp, operands[0])
                    if lhs_shape is not None and cm.group(1):
                        for idx in cm.group(1).split(","):
                            i = int(idx)
                            if i < len(lhs_shape):
                                contract *= lhs_shape[i]
                a.flops += 2.0 * res_elems * contract * scale
            elif op.opcode == "fusion":
                cm = _CALL_RE.search(op.rest)
                if cm and cm.group(1) in self.comps:
                    sub = self.comps[cm.group(1)]
                    a.flops += self._dot_flops(sub, None) * scale
            # ---- bytes ----------------------------------------------------
            if self.kernel_substitute and op.region is not None:
                # A fused kernel-region op: charge only the traffic that
                # crosses the region boundary (slice-aware for fusions);
                # interior values stay in VMEM.
                if op.opcode == "fusion":
                    in_map, out_b = self._fusion_io(op, syms)
                else:
                    in_map = {n: syms.get(n, 0)
                              for n in _operand_names(op.rest)}
                    out_b = float(op.result_bytes)
                full = sum(in_map.values()) + out_b
                io = 0.0
                for n, b in in_map.items():
                    if region_of.get(n) != op.region:
                        io += b             # value entering the kernel
                if op.name in consumed_outside:
                    io += out_b             # value leaving the kernel
                a.hbm_bytes += io * scale
                a.kernel_boundary_bytes += io * scale
                a.kernel_bytes_saved += max(0.0, full - io) * scale
                continue
            if oc in ("dynamic-slice", "gather"):
                a.hbm_bytes += 2.0 * op.result_bytes * scale
            elif oc == "dynamic-update-slice":
                operands = _operand_names(op.rest)
                upd = syms.get(operands[1], 0) if len(operands) > 1 else 0
                a.hbm_bytes += 2.0 * upd * scale
            elif oc == "scatter":
                operands = _operand_names(op.rest)
                upd = syms.get(operands[-1], 0) if operands else 0
                a.hbm_bytes += 2.0 * upd * scale
            elif oc == "fusion":
                a.hbm_bytes += self._fusion_bytes(op, syms) * scale
            else:
                opb = sum(syms.get(n, 0) for n in _operand_names(op.rest))
                a.hbm_bytes += (opb + op.result_bytes) * scale


def analyze_hlo(text: str, n_devices: int,
                entry: str | None = None,
                kernel_substitute: bool = False) -> HloAnalysis:
    """``kernel_substitute=True`` re-costs ops inside ``pallas:`` named
    scopes as a fused kernel: interior traffic → VMEM (dropped), only
    boundary values count.  This models the measured Pallas kernels
    replacing the XLA-fallback attention/WKV/RG-LRU paths on real TPUs
    (EXPERIMENTS.md §Perf)."""
    comps = _parse_computations(text)
    if not comps:
        raise ValueError("no computations parsed from HLO text")
    if entry is None:
        # ENTRY computation: the one whose name starts with 'main'
        entry = next((n for n in comps if n.startswith("main")),
                     next(iter(comps)))
    w = _Walker(comps, n_devices, kernel_substitute=kernel_substitute)
    w.walk(entry)
    return w.analysis


@dataclass
class Terms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_total: float
    hlo_flops_per_dev: float
    useful_ratio: float
    dominant: str

    def to_json(self) -> dict:
        return self.__dict__.copy()


def roofline_terms(analysis: HloAnalysis, n_chips: int,
                   model_flops_total: float,
                   n_links: int = 4) -> Terms:
    compute = analysis.flops / HW.PEAK_FLOPS
    memory = analysis.hbm_bytes / HW.HBM_BW
    coll = analysis.collective_bytes / (n_links * HW.ICI_BW)
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    useful = model_flops_total / max(analysis.flops * n_chips, 1.0)
    return Terms(compute, memory, coll, model_flops_total,
                 analysis.flops, useful, dominant)


def model_flops(cfg, shape, kind: str) -> float:
    """6·N·D (train) / 2·N·D (prefill) / 2·N·B (decode), N = active."""
    _, active = cfg.param_count()
    if kind == "train":
        return 6.0 * active * shape.global_batch * shape.seq_len
    if kind == "prefill":
        return 2.0 * active * shape.global_batch * shape.seq_len
    return 2.0 * active * shape.global_batch
