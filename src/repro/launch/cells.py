"""Wire an (arch × shape × mesh) cell into a jit-able function plus
ShapeDtypeStruct inputs (weak-type-correct, shardable, no allocation).

``build_cell`` is what both the dry-run driver and the roofline analyzer
consume.  Per-arch training knobs (gradient-accumulation depth, gradient /
optimizer state dtypes) live in ``TRAIN_KNOBS`` — chosen so every cell's
parameters + optimizer states + scan residuals fit a 16 GB v5e chip
(verified via ``compiled.memory_analysis()``; see EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, ShapeSpec, cell_runnable, get_config
from ..models import (ModelConfig, Rules, init_cache,
                      init_params, param_specs, prefill)
from ..optim import AdamWConfig, adamw_init
from ..train.steps import StepConfig, make_serve_step, make_train_step
from .mesh import rules_for_mesh

__all__ = ["SkipCell", "CellSpec", "build_cell", "TRAIN_KNOBS"]


class SkipCell(Exception):
    """Raised when an (arch × shape) cell is N/A (reason in args[0])."""


@dataclass(frozen=True)
class TrainKnobs:
    accum: int = 1
    grad_dtype: str = "float32"
    opt_dtype: str = "float32"
    ce_seq_chunk: int = 512


TRAIN_KNOBS: dict[str, TrainKnobs] = {
    "internvl2-1b": TrainKnobs(accum=1),
    "gemma2-9b": TrainKnobs(accum=2),
    "deepseek-coder-33b": TrainKnobs(accum=8),
    "llama3.2-1b": TrainKnobs(accum=1),
    "qwen1.5-110b": TrainKnobs(accum=16),
    "mixtral-8x22b": TrainKnobs(accum=8),
    "llama4-maverick-400b-a17b": TrainKnobs(
        accum=8, grad_dtype="bfloat16", opt_dtype="bfloat16"),
    "musicgen-medium": TrainKnobs(accum=4),
    "recurrentgemma-2b": TrainKnobs(accum=2),
    "rwkv6-7b": TrainKnobs(accum=4),
}


@dataclass
class CellSpec:
    arch: str
    shape: ShapeSpec
    cfg: ModelConfig
    fn: Any                 # jit-able python callable
    args: tuple             # ShapeDtypeStructs (positional)
    donate: tuple[int, ...]
    kind: str               # train | prefill | decode
    static_notes: dict


def _sds(shapes, specs, mesh):
    def mk(s, spec):
        return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(mk, shapes, specs,
                        is_leaf=lambda x: isinstance(
                            x, jax.ShapeDtypeStruct))


def _batch_spec(n: int, mesh, rules: Rules, extra_dims: int,
                lead: tuple = ()) -> P:
    """Batch sharding, falling back to replication when not divisible."""
    total = 1
    for a in rules.batch:
        total *= mesh.shape[a]
    first = rules.batch if n % total == 0 else None
    return P(*lead, first, *([None] * extra_dims))


def build_cell(arch: str, shape_name: str, mesh,
               cfg_overrides: dict | None = None,
               rules_overrides: dict | None = None,
               knobs: TrainKnobs | None = None,
               cache_shard: str = "seq") -> CellSpec:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    rules = rules_for_mesh(mesh, rules_overrides)
    knobs = knobs or TRAIN_KNOBS[arch]
    if shape.kind == "train":
        cfg = cfg.replace(ce_seq_chunk=knobs.ce_seq_chunk)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    ok, why = cell_runnable(cfg, shape)
    if not ok:
        raise SkipCell(why)

    tp = mesh.shape["model"]
    pspecs = param_specs(cfg, rules, tp)
    p_shapes = jax.eval_shape(partial(init_params, cfg=cfg),
                              jax.random.PRNGKey(0))
    params_in = _sds(p_shapes, pspecs, mesh)
    B, S = shape.global_batch, shape.seq_len
    F = cfg.frontend_len
    S_tok = S - F
    n_batch = 1
    for a in rules.batch:
        n_batch *= mesh.shape[a]
    notes = {"tp": tp, "batch_devices": n_batch}

    if shape.kind == "train":
        opt_cfg = AdamWConfig(state_dtype=knobs.opt_dtype)
        # cap accumulation so the microbatch still spans the batch
        # devices (multi-pod has 2× the devices — and 2× the memory)
        A = min(knobs.accum, max(1, B // notes["batch_devices"]))
        step_cfg = StepConfig(accum=A, grad_dtype=knobs.grad_dtype)
        assert B % A == 0, (B, A)
        mB = B // A
        o_shapes = jax.eval_shape(partial(adamw_init, cfg=opt_cfg),
                                  p_shapes)
        from ..optim.adamw import opt_state_specs
        opt_in = _sds(o_shapes, opt_state_specs(pspecs), mesh)
        bspec = _batch_spec(mB, mesh, rules, 1, lead=(None,))
        batch = {
            "tokens": jax.ShapeDtypeStruct(
                (A, mB, S_tok), jnp.int32,
                sharding=NamedSharding(mesh, bspec)),
            "labels": jax.ShapeDtypeStruct(
                (A, mB, S), jnp.int32,
                sharding=NamedSharding(mesh, bspec)),
        }
        if F:
            pfspec = _batch_spec(mB, mesh, rules, 2, lead=(None,))
            batch["prefix"] = jax.ShapeDtypeStruct(
                (A, mB, F, cfg.d_model), jnp.bfloat16,
                sharding=NamedSharding(mesh, pfspec))
        step = jax.ShapeDtypeStruct((), jnp.int32,
                                    sharding=NamedSharding(mesh, P()))
        fn = make_train_step(cfg, rules, opt_cfg, step_cfg)
        notes["accum"] = A
        notes["micro_batch"] = mB
        return CellSpec(arch, shape, cfg, fn,
                        (params_in, opt_in, step, batch), (0, 1),
                        "train", notes)

    if shape.kind == "prefill":
        bspec = _batch_spec(B, mesh, rules, 1)
        tokens = jax.ShapeDtypeStruct(
            (B, S_tok), jnp.int32, sharding=NamedSharding(mesh, bspec))
        args = [params_in, tokens]
        if F:
            pf = jax.ShapeDtypeStruct(
                (B, F, cfg.d_model), jnp.bfloat16,
                sharding=NamedSharding(mesh, _batch_spec(B, mesh, rules, 2)))
            args.append(pf)

            def fn(params, tokens, prefix):
                return prefill(params, tokens, cfg, rules, max_len=S,
                               prefix=prefix)
        else:
            def fn(params, tokens):
                return prefill(params, tokens, cfg, rules, max_len=S)
        return CellSpec(arch, shape, cfg, fn, tuple(args), (),
                        "prefill", notes)

    # decode: one new token against a seq_len-deep cache
    c_shapes = jax.eval_shape(partial(init_cache, cfg, B, S))

    def _cache_spec(leaf):
        # Shard the batch dim wherever it sits: stacked block caches are
        # (n_units, B, …), remainder-layer caches are (B, …).  The cache
        # *sequence* dim additionally shards over the model axis
        # (context-parallel decode): scores/PV contractions over the
        # sharded kv sequence become GSPMD psums, and a 32k×128-batch
        # cache (e.g. llama4: 824 GB) fits per-device HBM.
        dims = leaf.shape
        spec: list = [None] * len(dims)
        i_b = -1
        if B > 1 and B % notes["batch_devices"] == 0:
            for i, d in enumerate(dims):
                if d == B:
                    spec[i] = rules.batch
                    i_b = i
                    break
        if cache_shard == "headdim" and len(dims) >= 4 \
                and dims[-1] % tp == 0:
            # shard D: token writes touch one slot (no select over the
            # seq shard); scores psum over D instead (§Perf variant)
            spec[-1] = rules.tp
            return P(*spec)
        for i in range(i_b + 1, len(dims)):
            if dims[i] >= 1024 and dims[i] % tp == 0:
                spec[i] = rules.tp
                break
        return P(*spec)

    cspecs = jax.tree.map(_cache_spec, c_shapes,
                          is_leaf=lambda x: isinstance(
                              x, jax.ShapeDtypeStruct))
    cache_in = _sds(c_shapes, cspecs, mesh)
    tok = jax.ShapeDtypeStruct(
        (B,), jnp.int32,
        sharding=NamedSharding(mesh, _batch_spec(B, mesh, rules, 0)))
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    fn = make_serve_step(cfg, rules)
    return CellSpec(arch, shape, cfg, fn, (params_in, tok, pos, cache_in),
                    (3,), "decode", notes)
