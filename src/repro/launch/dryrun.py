import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

Lowers + compiles every (architecture × input shape) cell against the
production meshes — 16×16 (single pod, 256 chips) and 2×16×16 (two pods,
512 chips) — and records ``memory_analysis()`` / ``cost_analysis()`` plus
the HLO-derived roofline terms to ``artifacts/dryrun/*.json``.

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first init); this module is the only place the 512
placeholder devices exist — tests and benchmarks see 1 device.

Usage:
    python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--single-pod]
"""

import argparse
import json
import pathlib
import time
import traceback

import jax

from ..configs import ARCH_IDS, SHAPES
from .cells import SkipCell, build_cell
from .mesh import HW, make_production_mesh
from .roofline import analyze_hlo, model_flops, roofline_terms

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_DT_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "pred": 1,
             "s8": 1, "u8": 1, "f64": 8, "s64": 8, "u64": 8}


def _legalization_excess(hlo: str) -> int:
    """Bytes of unique f32 shapes that also exist as bf16 buffers."""
    import re
    shapes: dict[str, set[str]] = {}
    for m in re.finditer(r"= (f32|bf16)\[([\d,]+)\]", hlo):
        shapes.setdefault(m.group(2), set()).add(m.group(1))
    excess = 0
    for dims, dts in shapes.items():
        if dts >= {"f32", "bf16"}:
            n = 1
            for d in dims.split(","):
                n *= int(d)
            if n * 4 > 50e6:        # only large buffers matter
                excess += n * 4
    return excess


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             cfg_overrides: dict | None = None,
             rules_overrides: dict | None = None,
             cache_shard: str = "seq", knobs=None,
             save: bool = True, verbose: bool = True,
             tag: str = "") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, cfg_overrides=cfg_overrides,
                      rules_overrides=rules_overrides,
                      cache_shard=cache_shard, knobs=knobs)
    with jax.set_mesh(mesh):
        jitted = jax.jit(cell.fn, donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    analysis = analyze_hlo(hlo, n_chips)
    mf = model_flops(cell.cfg, cell.shape, cell.kind)
    terms = roofline_terms(analysis, n_chips, mf)
    # second analysis: "pallas:" regions re-costed as fused kernels
    k_analysis = analyze_hlo(hlo, n_chips, kernel_substitute=True)
    k_terms = roofline_terms(k_analysis, n_chips, mf)

    # The CPU backend has no native bf16: XLA float-normalization clones
    # bf16 loop buffers into f32 twins (verified in tests/test_roofline).
    # On a bf16-native TPU those twins do not exist; subtract each unique
    # f32 shape that also appears as a bf16 buffer (conservative: once
    # per shape).
    legal_excess = _legalization_excess(hlo)
    mem_stats = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "bf16_legalization_excess_bytes": legal_excess,
        "peak_estimate_bytes": (mem.argument_size_in_bytes
                                + mem.temp_size_in_bytes
                                + mem.output_size_in_bytes
                                - mem.alias_size_in_bytes),
    }
    # never adjust below what args+outputs alone require
    floor = (mem_stats["argument_bytes"] + mem_stats["output_bytes"]
             - mem_stats["alias_bytes"])
    mem_stats["adjusted_peak_bytes"] = max(
        mem_stats["peak_estimate_bytes"] - legal_excess, floor)
    fits = mem_stats["adjusted_peak_bytes"] <= HW.HBM_BYTES
    record = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": cell.kind,
        "notes": cell.static_notes,
        "memory": mem_stats,
        "fits_16GB": bool(fits),
        "cost_analysis": {k: cost.get(k) for k in
                          ("flops", "bytes accessed", "transcendentals")
                          if k in cost},
        "hlo_analysis": analysis.to_json(),
        "terms": terms.to_json(),
        "kernel_analysis": k_analysis.to_json(),
        "kernel_terms": k_terms.to_json(),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "tag": tag,
    }
    if verbose:
        print(f"[{record['mesh']}] {arch} × {shape}  "
              f"({cell.kind}, {n_chips} chips)")
        print(f"  memory/device: args={mem_stats['argument_bytes']/1e9:.2f}GB "
              f"temp={mem_stats['temp_bytes']/1e9:.2f}GB "
              f"peak≈{mem_stats['peak_estimate_bytes']/1e9:.2f}GB "
              f"adj≈{mem_stats['adjusted_peak_bytes']/1e9:.2f}GB "
              f"{'FITS' if fits else 'OVER'} 16GB")
        print(f"  cost_analysis: flops/dev={cost.get('flops', 0):.3e} "
              f"(body-once) bytes={cost.get('bytes accessed', 0):.3e}")
        print(f"  hlo (trip-scaled): flops/dev={analysis.flops:.3e} "
              f"hbm={analysis.hbm_bytes:.3e}B "
              f"wire={analysis.collective_bytes:.3e}B "
              f"({analysis.collective_count} colls)")
        print(f"  terms: compute={terms.compute_s*1e3:.2f}ms "
              f"memory={terms.memory_s*1e3:.2f}ms "
              f"collective={terms.collective_s*1e3:.2f}ms "
              f"-> {terms.dominant}-bound; useful={terms.useful_ratio:.2f}")
        print(f"  w/kernels: memory={k_terms.memory_s*1e3:.2f}ms "
              f"-> {k_terms.dominant}-bound "
              f"(saved {k_analysis.kernel_bytes_saved/1e9:.1f}GB region "
              f"traffic, boundary {k_analysis.kernel_boundary_bytes/1e9:.1f}GB)")
    if save:
        ART_DIR.mkdir(parents=True, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        name = f"{arch}_{shape}_{record['mesh']}{suffix}.json".replace(
            "/", "-")
        (ART_DIR / name).write_text(json.dumps(record, indent=1))
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="run only the 2x16x16 mesh")
    ap.add_argument("--single-pod", action="store_true",
                    help="run only the 16x16 mesh")
    ap.add_argument("--no-save", action="store_true")
    args = ap.parse_args()

    meshes = [False, True]
    if args.multi_pod:
        meshes = [True]
    elif args.single_pod:
        meshes = [False]

    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    failures, skips = [], []
    for multi in meshes:
        for arch, shape in cells:
            try:
                run_cell(arch, shape, multi_pod=multi,
                         save=not args.no_save)
            except SkipCell as e:
                skips.append((arch, shape, str(e)))
                print(f"[skip] {arch} × {shape}: {e}")
            except Exception:
                failures.append((arch, shape, multi))
                print(f"[FAIL] {arch} × {shape} multi={multi}")
                traceback.print_exc()
    print(f"\n{len(cells)*len(meshes) - len(failures) - len(skips)} ok, "
          f"{len(skips)} skipped, {len(failures)} FAILED")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
