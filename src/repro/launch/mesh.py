"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state): 16×16 = 256 chips per pod (v5e), 2 pods = 512
chips multi-pod.  The ``pod`` axis composes with ``data`` for the
batch/FSDP dimension; ``model`` is the TP axis.
"""

from __future__ import annotations

import jax

from ..models.sharding import Rules

__all__ = ["make_production_mesh", "rules_for_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def rules_for_mesh(mesh: jax.sharding.Mesh,
                   overrides: dict | None = None) -> Rules:
    if "pod" in mesh.axis_names:
        b = ("pod", "data")
    else:
        b = ("data",)
    return Rules(batch=b, fsdp=b, tp="model", overrides=overrides or {})


class HW:
    """TPU v5e hardware constants (per chip) for the roofline terms."""

    PEAK_FLOPS = 197e12        # bf16
    HBM_BW = 819e9             # bytes/s
    ICI_BW = 50e9              # bytes/s per link
    HBM_BYTES = 16e9
