"""Training launcher.

    python -m repro.launch.train --arch llama3.2-1b --smoke --steps 50

``--smoke`` runs the reduced same-family config on the local device(s);
without it the full config is used (requires a real TPU mesh — on this
host use ``repro.launch.dryrun`` instead, which is the compile-only
path for the production meshes).
"""

from __future__ import annotations

import argparse

from ..configs import get_config, get_smoke_config
from ..train.trainer import Trainer, TrainerConfig
from ..train.steps import StepConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke \
        else get_config(args.arch)
    tcfg = TrainerConfig(
        steps=args.steps, global_batch=args.global_batch,
        seq_len=args.seq_len, checkpoint_dir=args.checkpoint_dir,
        compress=args.compress, seed=args.seed,
        step=StepConfig(accum=args.accum))
    trainer = Trainer(cfg, tcfg)
    if trainer.maybe_restore():
        print(f"restored from step {trainer.step}")
    try:
        hist = trainer.run()
        print(f"final loss: {hist[-1]['loss']:.4f} "
              f"(over {len(hist)} steps)")
    finally:
        trainer.close()


if __name__ == "__main__":
    main()
