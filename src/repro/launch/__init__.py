"""Launch tier: production meshes, per-cell jit wiring, the multi-pod
dry-run driver and the roofline analyzer."""
