"""Serving launcher — continuous batching + prediction autoscaling demo.

    python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --requests 16 --policy prediction
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, get_smoke_config
from ..core import policy_entry, registered_policies
from ..models import init_params
from ..serving import AutoScaler, Request, ServingEngine


def main() -> None:
    # Any registered non-sharing policy can drive the autoscaler —
    # new policies show up here without touching this launcher.
    policies = [p for p in registered_policies()
                if not policy_entry(p).sharing]
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--policy", default="prediction", choices=policies)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke \
        else get_config(args.arch)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                           max_len=128)
    scaler = AutoScaler(engine.monitor, max_replicas=args.max_batch,
                        policy=args.policy, bus=engine.bus)
    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(4, 24)) \
            .tolist()
        reqs.append(engine.submit(Request(prompt=prompt,
                                          max_new_tokens=args.max_new)))
    targets = []
    while engine.load:
        targets.append(scaler.target(len(engine.queue),
                                     sum(r is not None
                                         for r in engine.active)))
        engine.tick()
    wall = time.perf_counter() - t0
    lat = [r.done_at - r.submitted_at for r in reqs]
    print(f"{args.requests} requests, {engine.tokens_out} tokens in "
          f"{wall:.2f}s ({engine.tokens_out / wall:.1f} tok/s)")
    print(f"latency p50={np.percentile(lat, 50)*1e3:.0f}ms "
          f"p95={np.percentile(lat, 95)*1e3:.0f}ms")
    print(f"autoscaler Δ trace (first 20): {targets[:20]}")


if __name__ == "__main__":
    main()
